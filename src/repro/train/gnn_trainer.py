"""Distributed GNN training loop with the GreenDyGNN pipeline (Section V).

This trainer reproduces the paper's evaluation harness end-to-end:

  real graph -> METIS-like partition -> presampled mini-batch trace ->
  per-step feature resolution (local / cache-hit / remote miss) ->
  calibrated network-time + energy accounting -> per-boundary control
  (static / heuristic / RL) -> Table-I style reports.

Everything *discrete* is real (sampled batches, hit/miss streams, per-owner
byte counts); wall-clock network time and power are modeled by the
calibrated Eq. (4) RPC law — see DESIGN.md "Measured vs modeled" — or, with
``RunConfig.scenario`` set, by the ``repro.net`` discrete-event congestion
fabric (per-owner link queues, background traffic, trace replay; DESIGN.md
"Fabric vs closed form"). With
``async_pipeline=True`` the double-buffered rebuild itself is also real: a
``repro.pipeline.CacheBuilder`` thread plans and bulk-fetches the next hot
set while this loop consumes the active buffer, and a depth-Q
``PrefetchQueue`` resolves upcoming batch payloads ahead of time; rebuild
overlap and exposed stalls are then *measured*, replacing the analytic
``alpha_crit`` leak term (DESIGN.md "Measured vs modeled, revisited"). The
same loop optionally runs the actual jitted GraphSAGE train step
(``run_model=True``) so examples train a real model under the same pipeline.

Methods (paper Section VI-A + ablations VI-H):
  dgl          on-demand per-layer fetching, no cache
  bgl          prefetch-overlap pipeline, no adaptive cache
  rapidgnn     epoch-level static cache (presample once per epoch)
  static_w     windowed cache at fixed W (w/o-RL ablation at W=16)
  heuristic    windowed cache + Eq. 7 threshold rule
  greendygnn   windowed cache + Double-DQN controller (full system)
  greendygnn_nocw   RL for W only, uniform allocation (w/o cost weights)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import controller as ctl
from repro.core import cost_model as cm
from repro.core import domain_rand as dr
from repro.core.energy import EnergyMeter, StepSample
from repro.core.windowed_cache import CacheStats, DoubleBufferedCache
from repro.graph import datasets
from repro.graph.features import ShardedFeatureStore
from repro.graph.partition import partition_graph
from repro.graph.sampling import presample_epoch

METHODS = (
    "dgl", "bgl", "rapidgnn", "static_w", "heuristic",
    "greendygnn", "greendygnn_nocw",
)


@dataclasses.dataclass
class RunConfig:
    method: str = "greendygnn"
    dataset: str = "reddit"
    batch_size: int = 2000
    n_epochs: int = 30
    steps_per_epoch: int = 32
    fanouts: tuple = (10, 25)
    n_parts: int = 4
    cache_frac: float = 0.35        # RapidGNN-scale: ~100k / 233k on Reddit
    congested: bool = True           # paper schedule vs clean (closed form)
    fixed_delta_ms: float | tuple | None = None
                                     # override: constant injected delay [ms]
                                     # on EVERY owner link (scalar) or per
                                     # owner (length-(P-1) vector) —
                                     # calibration + Fig. 8 grids
    scenario: str | None = None      # net-fabric scenario (repro.net): e.g.
                                     # "clean", "paper_schedule",
                                     # "bursty_markov", "incast",
                                     # "trace:<path>". None/"closed_form"
                                     # keeps the analytic Eq. 4 law driven
                                     # by congested/fixed_delta_ms.
    static_window: int = 16
    warmup_epochs: int = 2
    batch_divisor: int = 10          # bench graphs are ~10x scaled: keep the
                                     # paper's batch/graph ratio
    locality_frac: float = 0.75      # fraction of each batch drawn from the
                                     # locality traversal (rest global)
    dgl_chunk: int = 512             # rows per fine-grained DistTensor RPC
    dgl_concurrency: int = 2         # in-flight RPCs (default DGL pipeline)
    prefetch_depth: int = 4          # Stage-3 async queue depth Q: cached
                                     # methods hide fetch latency behind up
                                     # to Q*t_base of lookahead (Section V-A)
    bgl_depth: int = 2               # BGL prefetches but shallower
    seed: int = 0
    params: cm.CostModelParams = dataclasses.field(
        default_factory=cm.CostModelParams
    )
    q_fn: Callable | None = None     # RL policy (greendygnn methods)
    run_model: bool = False          # also run the real jitted GNN step
    pad_blocks: bool = False         # static block shapes (jit-stable steps)
    bgl_overlap_frac: float = 0.75   # fraction of t_base usable to hide stall
    async_pipeline: bool = False     # run the REAL threaded builder/prefetch
                                     # pipeline (repro.pipeline) instead of
                                     # the analytic alpha_crit leak model;
                                     # windowed methods only


@dataclasses.dataclass
class RunResult:
    meter: EnergyMeter
    hit_rate_per_epoch: np.ndarray
    window_per_epoch: np.ndarray
    sigma_trace: np.ndarray
    accuracy_per_epoch: np.ndarray | None
    wall_time_per_epoch: np.ndarray
    # parity-harness observables: per-step hit/miss stream and cumulative
    # remotely-fetched rows by owner (cache rebuilds + per-step misses)
    step_hits: np.ndarray | None = None
    step_misses: np.ndarray | None = None
    fetched_rows_by_owner: np.ndarray | None = None
    pipeline: object | None = None   # PipelineReport when async_pipeline=True

    def totals(self) -> dict:
        return self.meter.totals_kj()


def build_trace(cfg: RunConfig):
    """Shared per-(dataset,batch) trace so all methods see identical load.

    Seeds are drawn in *locality order* (community-sorted with a rotating
    offset per epoch): consecutive mini-batches expand nearby neighborhoods,
    so the hot remote set drifts within the epoch — the physical driver of
    the paper's decaying h(W) (fresh small-window caches track the drift,
    epoch-level caches cannot; Section II-C)."""
    graph = datasets.materialize(cfg.dataset, seed=0)
    owner = partition_graph(graph, cfg.n_parts, seed=0)
    rng = np.random.default_rng(cfg.seed + 17)
    local_nodes = np.where(owner == 0)[0]
    # locality-ordered traversal: sort by community, jitter within community
    comm = graph.labels[local_nodes].astype(np.int64)
    order = np.lexsort((rng.random(len(local_nodes)), comm))
    local_sorted = local_nodes[order]
    batch = max(cfg.batch_size // max(cfg.batch_divisor, 1), 32)
    mbs = []
    for epoch in range(cfg.n_epochs):
        # rotate the traversal start each epoch (epoch-shuffled locality)
        roll = rng.integers(0, len(local_sorted))
        epoch_nodes = np.roll(local_sorted, roll)
        mbs.append(
            presample_epoch(
                graph, epoch_nodes, batch, list(cfg.fanouts),
                cfg.steps_per_epoch, rng, pad=cfg.pad_blocks,
                sequential=True, locality_frac=cfg.locality_frac,
            )
        )
    traces = [[mb.input_nodes for mb in epoch] for epoch in mbs]
    return graph, owner, traces, mbs


def _closed_form_delta(cfg: RunConfig, epoch: int, n_owners: int) -> np.ndarray:
    """Injected per-owner delay [ms] for the analytic (non-fabric) path."""
    if cfg.fixed_delta_ms is not None:
        fd = np.asarray(cfg.fixed_delta_ms, np.float64).ravel()
        if fd.size == 1:
            return np.full(n_owners, fd[0])
        if fd.size != n_owners:
            raise ValueError(
                f"fixed_delta_ms has {fd.size} entries, run has "
                f"{n_owners} owner links"
            )
        return fd.copy()
    if cfg.congested:
        return np.asarray(dr.paper_schedule_delta(epoch, cfg.n_epochs, n_owners))
    return np.zeros(n_owners)


def _fetch_time(params, per_owner_rows: np.ndarray, delta_ms: np.ndarray,
                bytes_per_row: float) -> tuple[float, float, float, int]:
    """ONE consolidated bulk RPC per owner, concurrently across owners.

    Two quantities fall out (DESIGN.md "Measured vs modeled"):
      raw   — wall latency of the slowest owner: alpha + 2*delta (injected
              RTT) + Eq. 4 payload terms (Eq. 3 straggler semantics);
      cpu   — CPU *processing* time summed over owners (initiation +
              payload + delay-inflated protocol work; Eq. 4 without the
              passive network wait) — this is what draws p_cpu_rpc and is
              the paper's dominant energy term (Section VI-B).
    Returns (raw_s, cpu_s, bytes, n_rpcs)."""
    active = per_owner_rows > 0
    if not active.any():
        return 0.0, 0.0, 0.0, 0
    payload = per_owner_rows * bytes_per_row
    per_owner_t = (
        float(params.alpha_rpc)
        + float(params.beta) * payload
        + float(params.gamma_c) * payload * delta_ms
    )
    raw = float(np.max(np.where(active, per_owner_t + 2e-3 * delta_ms, 0.0)))
    cpu = float(np.sum(np.where(active, per_owner_t, 0.0)))
    return raw, cpu, float(payload.sum()), int(active.sum())


def _chunked_fetch_time(params, per_owner_rows: np.ndarray,
                        delta_ms: np.ndarray, bytes_per_row: float,
                        chunk: int, concurrency: int
                        ) -> tuple[float, float, float, int]:
    """Fine-grained DistTensor path (Default DGL / BGL): each owner's rows go
    as ceil(N/chunk) small RPCs with ``concurrency`` in flight, so the fixed
    initiation cost is paid ~n_chunks/Q times on the wall clock and
    n_chunks times on the CPU — the Fig. 1 regime where initiation
    dominates — plus one pipelined injected RTT."""
    active = per_owner_rows > 0
    if not active.any():
        return 0.0, 0.0, 0.0, 0
    n_chunks = np.ceil(per_owner_rows / chunk)
    payload = per_owner_rows * bytes_per_row
    payload_t = (
        float(params.beta) * payload
        + float(params.gamma_c) * payload * delta_ms
    )
    wall = (
        np.maximum(n_chunks / concurrency, 1.0) * float(params.alpha_rpc)
        + 0.5e-3 * delta_ms  # async client pipelines the injected RTT
        + payload_t
    )
    cpu_t = n_chunks * float(params.alpha_rpc) + payload_t
    raw = float(np.max(np.where(active, wall, 0.0)))
    cpu = float(np.sum(np.where(active, cpu_t, 0.0)))
    return raw, cpu, float(payload.sum()), int(n_chunks.sum())


def run(cfg: RunConfig, trace_bundle=None) -> RunResult:
    if trace_bundle is None:
        trace_bundle = build_trace(cfg)
    graph, owner, traces, mbs = trace_bundle
    params = cfg.params
    n_owners = cfg.n_parts - 1

    store = ShardedFeatureStore(graph.features, owner, 0, cfg.n_parts)
    owner_idx_map = store.owner_index(np.arange(graph.n_nodes))
    bytes_per_row = store.bytes_per_row

    # ---- network substrate: event fabric (scenario) or analytic Eq. 4 ----
    from repro.net import CLOSED_FORM, build_scenario

    fabric = None
    if cfg.scenario not in CLOSED_FORM:
        fabric = build_scenario(
            cfg.scenario, params=params, n_owners=n_owners, seed=cfg.seed,
            n_epochs=cfg.n_epochs, steps_per_epoch=cfg.steps_per_epoch,
        )

    def _net_bulk(per_owner_rows, delta):
        """ONE consolidated bulk RPC per owner through the active substrate.

        Returns (raw, cpu, bytes, n_rpcs, per_owner_s). ``per_owner_s`` is
        the fabric's measured per-owner wall latency (None on the analytic
        path, which reconstructs it from Eq. 4 where needed)."""
        rows = np.asarray(per_owner_rows, np.float64)
        if fabric is not None:
            tr = fabric.transfer(rows, bytes_per_row)
            return (*tr.astuple(), tr.per_owner_s)
        return (*_fetch_time(params, rows, delta, bytes_per_row), None)

    def _net_chunked(per_owner_rows, delta, at_s=None):
        """Fine-grained DistTensor round (DGL/BGL) through the substrate."""
        rows = np.asarray(per_owner_rows, np.float64)
        if fabric is not None:
            tr = fabric.transfer(
                rows, bytes_per_row, at_s=at_s,
                chunk=cfg.dgl_chunk, concurrency=cfg.dgl_concurrency,
            )
            return (*tr.astuple(), tr.per_owner_s)
        return (
            *_chunked_fetch_time(
                params, rows, delta, bytes_per_row,
                cfg.dgl_chunk, cfg.dgl_concurrency,
            ),
            None,
        )

    capacity = int(cfg.cache_frac * graph.n_nodes)
    windowed = cfg.method in (
        "static_w", "heuristic", "greendygnn", "greendygnn_nocw",
    )
    cached = windowed or cfg.method == "rapidgnn"
    cache = (
        DoubleBufferedCache(capacity, owner_idx_map, n_owners)
        if cached else None
    )

    # ---- controller ----
    adaptive = cfg.method in ("heuristic", "greendygnn", "greendygnn_nocw")
    controller = None
    if adaptive:
        from repro.core import policies as pol

        if cfg.method == "heuristic":
            policy = pol.heuristic_policy(params, cfg.static_window, n_owners)
            q_fn = pol.as_q_fn(policy, ctl.n_actions(n_owners))
        elif cfg.method == "greendygnn_nocw":
            assert cfg.q_fn is not None, "greendygnn methods need a trained q_fn"
            base = cfg.q_fn
            n_a = n_owners + 1

            def q_fn(state, _base=base, _na=n_a):
                q = np.asarray(_base(state), np.float64).copy()
                mask = (np.arange(len(q)) % _na) != 0
                q[mask] = -1e18  # uniform-allocation actions only
                return q
        else:
            assert cfg.q_fn is not None, "greendygnn methods need a trained q_fn"
            q_fn = cfg.q_fn
        controller = ctl.AdaptiveController(q_fn, params, n_owners)

    # ---- optional real model ----
    model_state = None
    if cfg.run_model:
        model_state = _init_model(graph, cfg)

    meter = EnergyMeter(params=params, n_nodes=cfg.n_parts)
    t_base = float(params.t_base)
    window = cfg.static_window if windowed else cfg.steps_per_epoch
    weights = np.full(n_owners, 1.0 / n_owners)

    hit_rates, windows_log, acc_log, sigma_log, wall_log = [], [], [], [], []
    e_baseline = None
    window_left = 0
    pending_rebuild_cost = 0.0
    window_stats = CacheStats()      # per-window cache stats (controller obs)
    meter_snapshot: dict = {}
    step_hits: list[int] = []        # parity-harness hit/miss stream
    step_misses: list[int] = []
    fetched_rows_by_owner = np.zeros(n_owners, np.float64)

    # ---- real threaded pipeline (Section V-A, measured) ----
    use_async = bool(cfg.async_pipeline) and windowed and cache is not None
    builder = prefetcher = None
    pending_ticket = None            # in-flight build for the NEXT window
    pending_window, pending_weights = window, weights
    if use_async:
        from repro.pipeline import CacheBuilder, PrefetchQueue

        builder = CacheBuilder(
            cache, lambda ids: store.features[np.asarray(ids, np.int64)],
            fabric=fabric, bytes_per_row=bytes_per_row,
        ).start()
        prefetcher = PrefetchQueue(
            lambda ids: store.features[np.asarray(ids, np.int64)],
            depth=max(int(cfg.prefetch_depth), 1),
        ).start()

    try:
        for epoch in range(cfg.n_epochs):
            if fabric is not None:
                # fabric path: delta/sigma are time-varying within the epoch;
                # refreshed per step below, epoch log gets the step mean
                fabric.tick(meter.wall_s, epoch * cfg.steps_per_epoch, epoch)
                delta = fabric.delta_ms()
                sigma_true = fabric.sigma()
                epoch_sigmas: list[np.ndarray] = []
            else:
                delta = _closed_form_delta(cfg, epoch, n_owners)
                sigma_true = np.asarray(
                    [float(cm.sigma_from_delta(params, d)) for d in delta]
                )
                sigma_log.append(sigma_true)
            epoch_stats = CacheStats()
            epoch_windows = []
            wall0 = meter.wall_s
            trace = traces[epoch]

            if cfg.method == "rapidgnn" and cache is not None:
                # epoch-level rebuild from the full presampled epoch trace
                remote = [store.remote_ids_of(t) for t in trace]
                plan = cache.plan_window(remote, weights)
                raw, cpu_rb, nbytes, nrpc, _ = _net_bulk(
                    plan.per_owner_fetched.astype(np.float64), delta
                )
                meter.record_background(cpu_rb, nbytes, nrpc)
                meter.record_step(
                    StepSample(0.0, float(params.alpha_crit) * raw, 0.0)
                )
                cache.swap(plan)
                fetched_rows_by_owner += plan.per_owner_fetched

            if prefetcher is not None:
                # Stage-3: resolve this epoch's batch payloads up to Q ahead
                prefetcher.schedule(list(trace))

            for step in range(cfg.steps_per_epoch):
                input_nodes = trace[step]
                remote_ids = store.remote_ids_of(input_nodes)

                if fabric is not None:
                    # advance the virtual network clock; congestion state is
                    # a function of (wall time, global step) only
                    fabric.tick(
                        meter.wall_s, epoch * cfg.steps_per_epoch + step, epoch
                    )
                    delta = fabric.delta_ms()
                    sigma_true = fabric.sigma()
                    epoch_sigmas.append(sigma_true)

                # ---- windowed rebuild boundary ----
                if windowed and window_left <= 0:
                    def _decide(exposed_stall: float):
                        """Controller decision from the just-finished window."""
                        obs_stats = (
                            window_stats if window_stats.hits + window_stats.misses
                            else epoch_stats
                        )
                        stats = _controller_stats(
                            obs_stats, meter, t_base, e_baseline,
                            step, cfg.steps_per_epoch, n_owners,
                            snapshot=meter_snapshot,
                            rebuild_stall=exposed_stall,
                        )
                        w, ww, _ = controller.decide(stats)
                        if cfg.method == "greendygnn_nocw":
                            ww = np.full(n_owners, 1.0 / n_owners)
                        return w, ww

                    adaptive_now = (
                        controller is not None and epoch >= cfg.warmup_epochs
                    )
                    if not use_async:
                        # -------- analytic double-buffer model (alpha_crit leak)
                        if adaptive_now:
                            window, weights = _decide(
                                pending_rebuild_cost / max(window, 1)
                            )
                        else:
                            window = cfg.static_window
                        window_stats = CacheStats()
                        meter_snapshot = {
                            "n": meter.n_steps, "wall": meter.wall_s,
                            "energy": meter.gpu_j + meter.cpu_j,
                        }
                        upcoming = [
                            store.remote_ids_of(t)
                            for t in trace[step : step + window]
                        ]
                        plan = cache.plan_window(upcoming, weights)
                        raw_rb, cpu_rb, nbytes, nrpc, _ = _net_bulk(
                            plan.per_owner_fetched.astype(np.float64), delta
                        )
                        # modeled: the fetch runs on a hypothetical builder
                        # thread (background CPU energy); alpha_crit of it leaks
                        # onto the critical path, amortized over the window.
                        # On the fabric, the rebuild's wire time additionally
                        # occupies the owner links, so subsequent miss fetches
                        # queue behind it — a separate, physically distinct
                        # contention effect the closed form cannot express
                        # (kept alongside the alpha_crit CPU leak by design;
                        # DESIGN.md "Fabric vs closed form")
                        meter.record_background(cpu_rb, nbytes, nrpc)
                        pending_rebuild_cost = float(params.alpha_crit) * raw_rb
                        cache.swap(plan)
                    else:
                        # -------- real threaded pipeline (measured wall times)
                        if pending_ticket is None:
                            # cold start: nothing was built ahead; the rebuild
                            # is fully exposed, exactly like the sync path
                            if adaptive_now:
                                window, weights = _decide(
                                    pending_rebuild_cost / max(window, 1)
                                )
                            else:
                                window = cfg.static_window
                            upcoming = [
                                store.remote_ids_of(t)
                                for t in trace[step : step + window]
                            ]
                            buf, exposed = builder.build_sync(upcoming, weights)
                        else:
                            buf, exposed = builder.wait(pending_ticket)
                            window, weights = pending_window, pending_weights
                            pending_ticket = None
                        builder.swap(buf)
                        plan = buf.plan
                        if buf.net is not None:
                            # bulk fetch already issued through the fabric on
                            # the builder thread (shared Fabric.transfer API)
                            raw_rb, cpu_rb, nbytes, nrpc = buf.net.astuple()
                        else:
                            raw_rb, cpu_rb, nbytes, nrpc = _fetch_time(
                                params,
                                plan.per_owner_fetched.astype(np.float64),
                                delta, bytes_per_row,
                            )
                        # measured: builder work burned real host CPU in the
                        # background; only the MEASURED exposed wait leaks onto
                        # the critical path (no alpha_crit approximation)
                        meter.record_background(
                            cpu_rb + buf.t_plan_s + buf.t_fetch_s, nbytes, nrpc
                        )
                        pending_rebuild_cost = exposed
                        # decide the NEXT window one boundary ahead so its
                        # rebuild can overlap this window's compute
                        if adaptive_now:
                            nxt_window, nxt_weights = _decide(
                                exposed / max(window, 1)
                            )
                        else:
                            nxt_window, nxt_weights = cfg.static_window, weights
                        g_next = epoch * cfg.steps_per_epoch + step + window
                        ne, ns = divmod(g_next, cfg.steps_per_epoch)
                        if ne < cfg.n_epochs:
                            upcoming = [
                                store.remote_ids_of(t)
                                for t in traces[ne][ns : ns + nxt_window]
                            ]
                            pending_ticket = builder.submit(upcoming, nxt_weights)
                            pending_window, pending_weights = (
                                nxt_window, nxt_weights,
                            )
                        window_stats = CacheStats()
                        meter_snapshot = {
                            "n": meter.n_steps, "wall": meter.wall_s,
                            "energy": meter.gpu_j + meter.cpu_j,
                        }
                    fetched_rows_by_owner += plan.per_owner_fetched
                    window_left = window
                epoch_windows.append(window)

                # ---- resolve features ----
                if prefetcher is not None:
                    # real payload gather, resolved ahead by the Stage-3 queue
                    # (timings land in the PipelineReport; classification below
                    # stays synchronous so the hit/miss stream is unperturbed)
                    prefetcher.get()
                if cache is not None:
                    # one searchsorted probe recorded into both stat sinks
                    miss_ids = cache.access(remote_ids, epoch_stats, window_stats)
                else:
                    miss_ids = remote_ids
                step_hits.append(len(remote_ids) - len(miss_ids))
                step_misses.append(len(miss_ids))
                per_owner = np.zeros(n_owners, np.float64)
                if len(miss_ids):
                    oi = owner_idx_map[miss_ids]
                    per_owner += np.bincount(oi, minlength=n_owners)
                    fetched_rows_by_owner += per_owner

                gpu_overlap = 0.0
                if cfg.method in ("dgl", "bgl"):
                    # fine-grained per-layer rounds of small DistTensor RPCs;
                    # the second layer round issues after the first completes
                    rows1 = np.floor(per_owner * 0.5)
                    s1, c1, b1, r1, po1 = _net_chunked(rows1, delta)
                    s2, c2, b2, r2, po2 = _net_chunked(
                        per_owner - rows1, delta,
                        at_s=(meter.wall_s + s1) if fabric is not None else None,
                    )
                    raw, cpu, nbytes, nrpc = s1 + s2, c1 + c2, b1 + b2, r1 + r2
                    per_owner_s = po1 + po2 if po1 is not None else None
                    if cfg.method == "bgl":
                        # BGL prefetches during sampling: part of the latency is
                        # hidden, and GPU idle energy drops further (Section II-B)
                        slack = cfg.bgl_depth * t_base
                        gpu_overlap = cfg.bgl_overlap_frac
                    else:
                        slack = 0.0
                else:
                    # consolidated bulk fetch of misses; the Stage-3 async queue
                    # (depth Q) resolves future batches ahead, hiding up to
                    # Q * t_base of latency — "when congestion inflates RPC
                    # latencies, the prefetcher can no longer resolve future
                    # batches quickly enough, and stalls reappear" (Section II-B)
                    raw, cpu, nbytes, nrpc, per_owner_s = _net_bulk(
                        per_owner, delta
                    )
                    slack = cfg.prefetch_depth * t_base

                stall = max(0.0, raw - slack)
                rebuild_stall = (
                    pending_rebuild_cost / max(window, 1) if windowed else 0.0
                )
                ar_penalty = float(params.kappa_ar) * max(sigma_true.max() - 1.0, 0)
                meter.record_step(
                    StepSample(
                        t_compute=t_base,
                        t_stall=stall + rebuild_stall + ar_penalty,
                        t_cpu_comm=cpu,
                        remote_bytes=nbytes,
                        n_rpcs=nrpc,
                        gpu_overlap=gpu_overlap,
                    )
                )

                # feed the fetch-time deque (per-owner per-RPC observations,
                # including the raw injected RTT so Eq. 8 can see congestion);
                # the fabric path uses the *measured* per-owner wall latency,
                # so queueing delays are visible to the controller too
                if controller is not None:
                    for o in range(n_owners):
                        if per_owner[o] > 0:
                            if per_owner_s is not None:
                                t_o = float(per_owner_s[o])
                            else:
                                payload_o = per_owner[o] * bytes_per_row
                                t_o = (
                                    float(params.alpha_rpc)
                                    + 2e-3 * delta[o]
                                    + float(params.beta) * payload_o
                                    + float(params.gamma_c) * payload_o * delta[o]
                                )
                            controller.deque.append(o, t_o / max(per_owner[o], 1))

                if cfg.run_model and model_state is not None:
                    model_state = _model_step(model_state, mbs[epoch][step])

                window_left -= 1

            # ---- end of epoch ----
            meter.mark_epoch()
            if fabric is not None:
                sigma_log.append(
                    np.mean(epoch_sigmas, axis=0) if epoch_sigmas else sigma_true
                )
            hit_rates.append(epoch_stats.hit_rate())
            windows_log.append(float(np.mean(epoch_windows)) if epoch_windows else 0)
            wall_log.append(meter.wall_s - wall0)
            if cfg.run_model and model_state is not None:
                acc_log.append(_model_eval(model_state, graph))
            if controller is not None and epoch == cfg.warmup_epochs - 1:
                controller.observe_warmup()
            if epoch == cfg.warmup_epochs - 1:
                kj = meter.totals_kj()["total_kj"]
                steps = cfg.warmup_epochs * cfg.steps_per_epoch
                e_baseline = kj * 1e3 / max(steps, 1) / cfg.n_parts

    finally:
        # threads must not outlive the run, even on error paths
        if builder is not None:
            builder.stop()
        if prefetcher is not None:
            prefetcher.stop()

    report = None
    if use_async:
        from repro.pipeline import PipelineReport

        report = PipelineReport.from_components(builder, prefetcher)

    return RunResult(
        meter=meter,
        hit_rate_per_epoch=np.asarray(hit_rates),
        window_per_epoch=np.asarray(windows_log),
        sigma_trace=np.asarray(sigma_log),
        accuracy_per_epoch=np.asarray(acc_log) if acc_log else None,
        wall_time_per_epoch=np.asarray(wall_log),
        step_hits=np.asarray(step_hits, np.int64),
        step_misses=np.asarray(step_misses, np.int64),
        fetched_rows_by_owner=fetched_rows_by_owner,
        pipeline=report,
    )


def _controller_stats(
    stats: CacheStats, meter: EnergyMeter, t_base: float,
    e_baseline: float | None, step: int, steps_per_epoch: int, n_owners: int,
    snapshot: dict | None = None, rebuild_stall: float = 0.0,
) -> ctl.ControllerStats:
    """Observations over the LAST WINDOW (meter delta since ``snapshot``) —
    the same quantities the simulator's _observe emits, so the deployed
    state distribution matches training (sim-to-real, Section IV-C.2b)."""
    per_owner = (
        stats.per_owner_hit_rates()
        if stats.per_owner_hits is not None
        else np.zeros(n_owners)
    )
    if snapshot:
        d_steps = max(meter.n_steps - snapshot["n"], 1)
        t_step = (meter.wall_s - snapshot["wall"]) / d_steps
        e_step = (
            meter.gpu_j + meter.cpu_j - snapshot["energy"]
        ) / d_steps
    else:
        n = max(meter.n_steps, 1)
        t_step = meter.wall_s / n
        e_step = (meter.gpu_j + meter.cpu_j) / n
    return ctl.ControllerStats(
        owner_hit_rates=per_owner,
        global_hit_rate=stats.hit_rate(),
        t_step=t_step,
        f_rebuild=rebuild_stall / max(t_step, 1e-9),
        f_miss=max(0.0, (t_step - t_base - rebuild_stall) / max(t_step, 1e-9)),
        e_step=e_step,
        e_baseline=e_baseline if e_baseline else e_step,
        batches_remaining=1.0 - step / steps_per_epoch,
    )


# --------------------------------------------------------------- real model
def _init_model(graph, cfg: RunConfig):
    import jax
    import jax.numpy as jnp

    from repro import optim
    from repro.models.gnn import sage

    mcfg = sage.SageConfig(
        d_in=graph.features.shape[1], d_hidden=16,
        n_classes=int(graph.labels.max()) + 1, n_layers=2, dropout=0.0,
    )
    params, _ = sage.init(jax.random.PRNGKey(cfg.seed), mcfg)
    opt = optim.adamw(3e-3)

    @jax.jit
    def step(params, opt_state, x_in, blocks_flat, labels):
        def loss_fn(p):
            from repro.models.gnn.common import cross_entropy

            logits = sage.apply_blocks(p, mcfg, x_in, blocks_flat)
            return cross_entropy(logits, labels)

        l, g = jax.value_and_grad(loss_fn)(params)
        upd, new_state = opt.update(g, opt_state, params)
        return optim.apply_updates(params, upd), new_state, l

    return {
        "params": params, "opt_state": opt.init(params), "cfg": mcfg,
        "step": step, "graph": graph, "losses": [],
    }


def _model_step(state, mb):
    import jax.numpy as jnp

    graph = state["graph"]
    blocks = [
        {
            "edge_src": jnp.asarray(b.edge_src),
            "edge_dst": jnp.asarray(b.edge_dst),
            "edge_mask": jnp.asarray(b.edge_mask),
            "dst_pos": jnp.asarray(b.dst_pos),
        }
        for b in mb.blocks
    ]
    x_in = jnp.asarray(graph.features[mb.input_nodes])
    labels = jnp.asarray(graph.labels[mb.seeds])
    params, opt_state, loss = state["step"](
        state["params"], state["opt_state"], x_in, blocks, labels
    )
    state["params"], state["opt_state"] = params, opt_state
    state["losses"].append(float(loss))
    return state


def _model_eval(state, graph, n_eval: int = 2048):
    import jax.numpy as jnp

    from repro.models.gnn import sage
    from repro.models.gnn.common import accuracy

    x = jnp.asarray(graph.features[:n_eval])
    # evaluate on the induced subgraph of the first n_eval nodes
    ei = graph.edge_index
    m = (ei[0] < n_eval) & (ei[1] < n_eval)
    logits = sage.apply_full(
        state["params"], state["cfg"], x, jnp.asarray(ei[:, m])
    )
    return float(accuracy(logits, jnp.asarray(graph.labels[:n_eval])))
