"""Distributed GNN training loop with the GreenDyGNN pipeline (Section V).

This trainer reproduces the paper's evaluation harness end-to-end:

  real graph -> METIS-like partition -> presampled mini-batch trace ->
  per-step feature resolution (local / cache-hit / remote miss) ->
  calibrated network-time + energy accounting -> per-boundary control
  (static / heuristic / RL) -> Table-I style reports.

Everything *discrete* is real (sampled batches, hit/miss streams, per-owner
byte counts); wall-clock network time and power are modeled by the
calibrated Eq. (4) RPC law — see DESIGN.md "Measured vs modeled" — or, with
``RunConfig.scenario`` set, by the ``repro.net`` discrete-event congestion
fabric (per-owner link queues, background traffic, trace replay; DESIGN.md
"Fabric vs closed form"). With
``async_pipeline=True`` the double-buffered rebuild itself is also real: a
``repro.pipeline.CacheBuilder`` thread plans and bulk-fetches the next hot
set while this loop consumes the active buffer, and a depth-Q
``PrefetchQueue`` resolves upcoming batch payloads ahead of time; rebuild
overlap and exposed stalls are then *measured*, replacing the analytic
``alpha_crit`` leak term (DESIGN.md "Measured vs modeled, revisited"). The
same loop optionally runs the actual jitted GraphSAGE train step
(``run_model=True``) so examples train a real model under the same pipeline.

Methods (paper Section VI-A + ablations VI-H):
  dgl          on-demand per-layer fetching, no cache
  bgl          prefetch-overlap pipeline, no adaptive cache
  rapidgnn     epoch-level static cache (presample once per epoch)
  static_w     windowed cache at fixed W (w/o-RL ablation at W=16)
  heuristic    windowed cache + Eq. 7 threshold rule
  greendygnn   windowed cache + Double-DQN controller (full system)
  greendygnn_nocw   RL for W only, uniform allocation (w/o cost weights)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import controller as ctl
from repro.core import cost_model as cm
from repro.core import domain_rand as dr
from repro.core.energy import EnergyMeter, StepSample
from repro.core.windowed_cache import CacheStats, DoubleBufferedCache
from repro.graph import datasets
from repro.graph.features import ShardedFeatureStore
from repro.graph.partition import partition_graph
from repro.graph.sampling import presample_epoch

METHODS = (
    "dgl", "bgl", "rapidgnn", "static_w", "heuristic",
    "greendygnn", "greendygnn_nocw",
)


@dataclasses.dataclass
class RunConfig:
    method: str = "greendygnn"
    dataset: str = "reddit"
    batch_size: int = 2000
    n_epochs: int = 30
    steps_per_epoch: int = 32
    fanouts: tuple = (10, 25)
    n_parts: int = 4
    cache_frac: float = 0.35        # RapidGNN-scale: ~100k / 233k on Reddit
    congested: bool = True           # paper schedule vs clean (closed form)
    fixed_delta_ms: float | tuple | None = None
                                     # override: constant injected delay [ms]
                                     # on EVERY owner link (scalar) or per
                                     # owner (length-(P-1) vector) —
                                     # calibration + Fig. 8 grids
    scenario: str | None = None      # net-fabric scenario (repro.net): e.g.
                                     # "clean", "paper_schedule",
                                     # "bursty_markov", "incast",
                                     # "trace:<path>". None/"closed_form"
                                     # keeps the analytic Eq. 4 law driven
                                     # by congested/fixed_delta_ms.
    static_window: int = 16
    warmup_epochs: int = 2
    batch_divisor: int = 10          # bench graphs are ~10x scaled: keep the
                                     # paper's batch/graph ratio
    locality_frac: float = 0.75      # fraction of each batch drawn from the
                                     # locality traversal (rest global)
    dgl_chunk: int = 512             # rows per fine-grained DistTensor RPC
    dgl_concurrency: int = 2         # in-flight RPCs (default DGL pipeline)
    prefetch_depth: int = 4          # Stage-3 async queue depth Q: cached
                                     # methods hide fetch latency behind up
                                     # to Q*t_base of lookahead (Section V-A)
    bgl_depth: int = 2               # BGL prefetches but shallower
    seed: int = 0
    params: cm.CostModelParams = dataclasses.field(
        default_factory=cm.CostModelParams
    )
    q_fn: Callable | None = None     # RL policy (greendygnn methods)
    run_model: bool = False          # also run the real jitted GNN step
    pad_blocks: bool = False         # static block shapes (jit-stable steps)
    bgl_overlap_frac: float = 0.75   # fraction of t_base usable to hide stall
    async_pipeline: bool = False     # run the REAL threaded builder/prefetch
                                     # pipeline (repro.pipeline) instead of
                                     # the analytic alpha_crit leak model;
                                     # windowed methods only
    mem_budget: object | None = None  # repro.store.MemoryBudget: tiered
                                     # out-of-core store with a host-tier
                                     # byte budget. None (or an unlimited
                                     # budget) keeps the legacy monolithic
                                     # in-RAM store bit-for-bit.
    compute: str = "modeled"         # "measured" runs the real jitted SAGE
                                     # step (train/compute.ComputeEngine)
                                     # each trainer step and charges its
                                     # measured wall time where t_base is
                                     # charged today. "modeled" keeps the
                                     # constant-t_base lane bit-for-bit.
    grad_compression: str = "none"   # measured-lane gradient sync scheme:
                                     # "none" | "int8" | "topk" (error
                                     # feedback; wire bytes feed the ring
                                     # collective in cluster runs)
    topk_frac: float = 0.05          # kept fraction for "topk"
    trace: bool = False              # greentrace: record virtual-time span/
                                     # counter/charge events (repro.obs).
                                     # False keeps the modeled lane
                                     # bit-for-bit (null tracer, zero event
                                     # work on the hot path).


@dataclasses.dataclass
class RunResult:
    meter: EnergyMeter
    hit_rate_per_epoch: np.ndarray
    window_per_epoch: np.ndarray
    sigma_trace: np.ndarray
    accuracy_per_epoch: np.ndarray | None
    wall_time_per_epoch: np.ndarray
    # parity-harness observables: per-step hit/miss stream and cumulative
    # remotely-fetched rows by owner (cache rebuilds + per-step misses)
    step_hits: np.ndarray | None = None
    step_misses: np.ndarray | None = None
    fetched_rows_by_owner: np.ndarray | None = None
    pipeline: object | None = None   # PipelineReport when async_pipeline=True
    tier_counts: dict | None = None  # TierStats.counts() when the run used a
                                     # budgeted tiered store (outside the
                                     # digest surface; compared separately)
    compute_report: dict | None = None  # ComputeEngine.report() when the run
                                     # used compute="measured" (losses and
                                     # step timings; outside the digest
                                     # surface — see digest.measured_*)
    trace: dict | None = None        # greentrace payload (cfg.trace=True):
                                     # per-rank section from the worker,
                                     # wrapped into the full run payload by
                                     # run()/run_cluster (outside the digest
                                     # surface — the trace OBSERVES the run)

    def totals(self) -> dict:
        return self.meter.totals_kj()


def build_trace(cfg: RunConfig, rank: int = 0, rng=None, graph=None,
                owner=None):
    """Shared per-(dataset,batch) trace so all methods see identical load.

    Seeds are drawn in *locality order* (community-sorted with a rotating
    offset per epoch): consecutive mini-batches expand nearby neighborhoods,
    so the hot remote set drifts within the epoch — the physical driver of
    the paper's decaying h(W) (fresh small-window caches track the drift,
    epoch-level caches cannot; Section II-C).

    ``rank``/``rng``/``graph``/``owner`` support the cluster driver: every
    worker presamples from ITS partition of the shared graph with its own
    ``SeedSequence``-spawned stream (see ``worker.worker_rngs``). The
    defaults reproduce the legacy rank-0 trace bit-for-bit."""
    if graph is None:
        # greenlint: literal-ok — the graph/partition are fixtures shared by
        # every method and seed; plumbing cfg.seed here would change the
        # dataset per run and break cross-method comparability
        graph = datasets.materialize(cfg.dataset, seed=0)
    if owner is None:
        # greenlint: literal-ok — same fixture contract as the dataset above:
        # the partition layout is shared by every method/seed on purpose
        owner = partition_graph(graph, cfg.n_parts, seed=0)
    if rng is None:
        rng = np.random.default_rng(cfg.seed + 17)
    local_nodes = np.where(owner == rank)[0]
    # locality-ordered traversal: sort by community, jitter within community
    comm = graph.labels[local_nodes].astype(np.int64)
    order = np.lexsort((rng.random(len(local_nodes)), comm))
    local_sorted = local_nodes[order]
    batch = max(cfg.batch_size // max(cfg.batch_divisor, 1), 32)
    mbs = []
    for epoch in range(cfg.n_epochs):
        # rotate the traversal start each epoch (epoch-shuffled locality)
        roll = rng.integers(0, len(local_sorted))
        epoch_nodes = np.roll(local_sorted, roll)
        mbs.append(
            presample_epoch(
                graph, epoch_nodes, batch, list(cfg.fanouts),
                cfg.steps_per_epoch, rng, pad=cfg.pad_blocks,
                sequential=True, locality_frac=cfg.locality_frac,
            )
        )
    traces = [[mb.input_nodes for mb in epoch] for epoch in mbs]
    return graph, owner, traces, mbs


def _closed_form_delta(cfg: RunConfig, epoch: int, n_owners: int) -> np.ndarray:
    """Injected per-owner delay [ms] for the analytic (non-fabric) path."""
    if cfg.fixed_delta_ms is not None:
        fd = np.asarray(cfg.fixed_delta_ms, np.float64).ravel()
        if fd.size == 1:
            return np.full(n_owners, fd[0])
        if fd.size != n_owners:
            raise ValueError(
                f"fixed_delta_ms has {fd.size} entries, run has "
                f"{n_owners} owner links"
            )
        return fd.copy()
    if cfg.congested:
        return np.asarray(dr.paper_schedule_delta(epoch, cfg.n_epochs, n_owners))
    return np.zeros(n_owners)


def _fetch_time(params, per_owner_rows: np.ndarray, delta_ms: np.ndarray,
                bytes_per_row: float) -> tuple[float, float, float, int]:
    """ONE consolidated bulk RPC per owner, concurrently across owners.

    Two quantities fall out (DESIGN.md "Measured vs modeled"):
      raw   — wall latency of the slowest owner: alpha + 2*delta (injected
              RTT) + Eq. 4 payload terms (Eq. 3 straggler semantics);
      cpu   — CPU *processing* time summed over owners (initiation +
              payload + delay-inflated protocol work; Eq. 4 without the
              passive network wait) — this is what draws p_cpu_rpc and is
              the paper's dominant energy term (Section VI-B).
    Returns (raw_s, cpu_s, bytes, n_rpcs)."""
    active = per_owner_rows > 0
    if not active.any():
        return 0.0, 0.0, 0.0, 0
    payload = per_owner_rows * bytes_per_row
    per_owner_t = cm.rpc_cpu_s(
        float(params.alpha_rpc), float(params.beta), float(params.gamma_c),
        payload, delta_ms,
    )
    raw = float(np.max(np.where(
        active, per_owner_t + cm.PROP_RTT_BULK_S_PER_MS * delta_ms, 0.0
    )))
    cpu = float(np.sum(np.where(active, per_owner_t, 0.0)))
    return raw, cpu, float(payload.sum()), int(active.sum())


def _chunked_fetch_time(params, per_owner_rows: np.ndarray,
                        delta_ms: np.ndarray, bytes_per_row: float,
                        chunk: int, concurrency: int
                        ) -> tuple[float, float, float, int]:
    """Fine-grained DistTensor path (Default DGL / BGL): each owner's rows go
    as ceil(N/chunk) small RPCs with ``concurrency`` in flight, so the fixed
    initiation cost is paid ~n_chunks/Q times on the wall clock and
    n_chunks times on the CPU — the Fig. 1 regime where initiation
    dominates — plus one pipelined injected RTT."""
    active = per_owner_rows > 0
    if not active.any():
        return 0.0, 0.0, 0.0, 0
    n_chunks = np.ceil(per_owner_rows / chunk)
    payload = per_owner_rows * bytes_per_row
    payload_t = (
        float(params.beta) * payload
        + float(params.gamma_c) * payload * delta_ms
    )
    wall = (
        np.maximum(n_chunks / concurrency, 1.0) * float(params.alpha_rpc)
        + cm.PROP_RTT_CHUNKED_S_PER_MS * delta_ms  # pipelined injected RTT
        + payload_t
    )
    cpu_t = n_chunks * float(params.alpha_rpc) + payload_t
    raw = float(np.max(np.where(active, wall, 0.0)))
    cpu = float(np.sum(np.where(active, cpu_t, 0.0)))
    return raw, cpu, float(payload.sum()), int(n_chunks.sum())


def run(cfg: RunConfig, trace_bundle=None) -> RunResult:
    """Single-trainer entry point — the P=1 special case of the cluster.

    Assembles one :class:`repro.train.worker.TrainerWorker` (partition 0's
    store/cache/controller/pipeline/meter) over a single-requester fabric
    and drives its epochs in a plain loop. The multi-worker generalization
    — P workers over ONE requester-aware fabric with emergent cross-worker
    congestion and a costed gradient-sync barrier — is
    ``repro.train.cluster.run_cluster``.
    """
    from repro.net import CLOSED_FORM, build_scenario
    from repro.train.worker import TrainerWorker

    if trace_bundle is None:
        trace_bundle = build_trace(cfg)

    # ---- network substrate: event fabric (scenario) or analytic Eq. 4 ----
    fabric = None
    if cfg.scenario not in CLOSED_FORM:
        fabric = build_scenario(
            cfg.scenario, params=cfg.params, n_owners=cfg.n_parts - 1,
            seed=cfg.seed, n_epochs=cfg.n_epochs,
            steps_per_epoch=cfg.steps_per_epoch,
        )

    worker = TrainerWorker(cfg, trace_bundle, rank=0, fabric=fabric)
    try:
        for epoch in range(cfg.n_epochs):
            worker.begin_epoch(epoch)
            for step in range(cfg.steps_per_epoch):
                worker.step(epoch, step)
            worker.end_epoch(epoch)
    finally:
        # threads must not outlive the run, even on error paths
        worker.close()
    res = worker.result()
    if res.trace is not None:
        from repro.obs import build_payload, run_meta

        res.trace = build_payload(
            [res.trace],
            meta=run_meta(
                cfg,
                scenario=(
                    "closed_form" if cfg.scenario in CLOSED_FORM
                    else cfg.scenario
                ),
                n_workers=1,
            ),
        )
    return res


def _controller_stats(
    stats: CacheStats, meter: EnergyMeter, t_base: float,
    e_baseline: float | None, step: int, steps_per_epoch: int, n_owners: int,
    snapshot: dict | None = None, rebuild_stall: float = 0.0,
    headroom: float = 1.0,
) -> ctl.ControllerStats:
    """Observations over the LAST WINDOW (meter delta since ``snapshot``) —
    the same quantities the simulator's _observe emits, so the deployed
    state distribution matches training (sim-to-real, Section IV-C.2b)."""
    per_owner = (
        stats.per_owner_hit_rates()
        if stats.per_owner_hits is not None
        else np.zeros(n_owners)
    )
    if snapshot:
        d_steps = max(meter.n_steps - snapshot["n"], 1)
        t_step = (meter.wall_s - snapshot["wall"]) / d_steps
        e_step = (
            meter.gpu_j + meter.cpu_j - snapshot["energy"]
        ) / d_steps
    else:
        n = max(meter.n_steps, 1)
        t_step = meter.wall_s / n
        e_step = (meter.gpu_j + meter.cpu_j) / n
    return ctl.ControllerStats(
        owner_hit_rates=per_owner,
        global_hit_rate=stats.hit_rate(),
        t_step=t_step,
        f_rebuild=rebuild_stall / max(t_step, 1e-9),
        f_miss=max(0.0, (t_step - t_base - rebuild_stall) / max(t_step, 1e-9)),
        e_step=e_step,
        e_baseline=e_baseline if e_baseline else e_step,
        batches_remaining=1.0 - step / steps_per_epoch,
        headroom=headroom,
    )


# --------------------------------------------------------------- real model
def _init_model(graph, cfg: RunConfig):
    import jax
    import jax.numpy as jnp

    from repro import optim
    from repro.models.gnn import sage

    mcfg = sage.SageConfig(
        d_in=graph.features.shape[1], d_hidden=16,
        n_classes=int(graph.labels.max()) + 1, n_layers=2, dropout=0.0,
    )
    params, _ = sage.init(jax.random.PRNGKey(cfg.seed), mcfg)
    opt = optim.adamw(3e-3)

    @jax.jit
    def step(params, opt_state, x_in, blocks_flat, labels):
        def loss_fn(p):
            from repro.models.gnn.common import cross_entropy

            logits = sage.apply_blocks(p, mcfg, x_in, blocks_flat)
            return cross_entropy(logits, labels)

        l, g = jax.value_and_grad(loss_fn)(params)
        upd, new_state = opt.update(g, opt_state, params)
        return optim.apply_updates(params, upd), new_state, l

    return {
        "params": params, "opt_state": opt.init(params), "cfg": mcfg,
        "step": step, "graph": graph, "losses": [],
    }


def _model_step(state, mb):
    import jax.numpy as jnp

    graph = state["graph"]
    blocks = [
        {
            "edge_src": jnp.asarray(b.edge_src),
            "edge_dst": jnp.asarray(b.edge_dst),
            "edge_mask": jnp.asarray(b.edge_mask),
            "dst_pos": jnp.asarray(b.dst_pos),
        }
        for b in mb.blocks
    ]
    x_in = jnp.asarray(graph.features[mb.input_nodes])
    labels = jnp.asarray(graph.labels[mb.seeds])
    params, opt_state, loss = state["step"](
        state["params"], state["opt_state"], x_in, blocks, labels
    )
    state["params"], state["opt_state"] = params, opt_state
    state["losses"].append(float(loss))
    return state


def _model_eval(state, graph, n_eval: int = 2048):
    import jax.numpy as jnp

    from repro.models.gnn import sage
    from repro.models.gnn.common import accuracy

    x = jnp.asarray(graph.features[:n_eval])
    # evaluate on the induced subgraph of the first n_eval nodes
    ei = graph.edge_index
    m = (ei[0] < n_eval) & (ei[1] < n_eval)
    logits = sage.apply_full(
        state["params"], state["cfg"], x, jnp.asarray(ei[:, m])
    )
    return float(accuracy(logits, jnp.asarray(graph.labels[:n_eval])))
