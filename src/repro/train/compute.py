"""Measured-compute lane: a real jitted GraphSAGE step on the hot path.

Modeled mode charges ``CostModelParams.t_base`` for every trainer step;
this module replaces that constant with the wall time of an actual
forward/backward/optimizer step over the feature payloads the step
resolved, with neighborhood aggregation dispatched through the
``kernels.segment_mm`` block-sparse format:

  * on an accelerator backend the Pallas kernel (``block_spmm``) runs
    compiled;
  * on CPU — where Pallas can only interpret — the same block-sparse
    format executes through the compiled XLA twin (``block_spmm_xla``),
    so the measured numbers are real compiled-step times everywhere.

The edge-list -> block conversion is numpy preprocessing, cached per
mini-batch in a bounded LRU so the steps inside a rebuild window reuse
their prepared batches (the conversion is amortized exactly like the
cache rebuild itself; see DESIGN.md "Measured vs modeled, part 3").
Dynamic block/src/dst counts are bucketed to powers of two so the jitted
step compiles once per size bucket; compilation happens ahead-of-time
(``.lower().compile()``) and is excluded from the measured step time.

The block path is parity-asserted against the ``models/gnn/common``
scatter reference (``check_parity``, run automatically on the first
step). Gradient sync flows through ``grad_compression`` with error
feedback; ``sync_wire_bytes`` is what the cluster driver feeds into
``ring_collective_cost`` in place of the uncompressed payload.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Callable

import numpy as np

from repro.train import grad_compression as gc

_SCHEMES = ("none", "int8", "topk")


def _bucket(n: int) -> int:
    """Next power of two >= n (min 1): bounds distinct jit signatures."""
    return 1 << max(int(n) - 1, 0).bit_length()


def sage_config(graph, d_hidden: int = 16):
    """The paper's training model (Section VI-A) sized for ``graph`` —
    the single config shared by the modeled-mode model runner
    (``gnn_trainer._init_model``) and the measured lane."""
    from repro.models.gnn import sage

    d_in = (
        graph.features.shape[1]
        if graph.features is not None
        else graph.feature_source.n_feat
    )
    return sage.SageConfig(
        d_in=d_in, d_hidden=d_hidden,
        n_classes=int(graph.labels.max()) + 1, n_layers=2, dropout=0.0,
    )


def model_wire_bytes(graph, scheme: str = "none", frac: float = 0.05) -> float:
    """Per-sync gradient payload bytes for the SAGE model on ``graph``
    under a compression scheme (abstract param shapes; nothing is
    materialized). ``scheme="none"`` equals the float32 gradient payload
    of ``cluster.default_grad_bytes`` bit-for-bit."""
    import jax

    from repro.models.gnn import sage

    params, _ = sage.init(jax.random.PRNGKey(0), sage_config(graph),
                          abstract=True)
    return float(gc.wire_bytes(params, scheme, frac))


class ComputeEngine:
    """Real jitted SAGE step + timing + compression for one worker.

    ``clock`` is injectable (monotonic, ``time.perf_counter`` by default)
    so the determinism harness can drive the measured lane with a virtual
    clock and pin the timing -> calibration plumbing numerically.
    """

    def __init__(self, graph, cfg, agg_impl: str = "auto",
                 clock: Callable[[], float] | None = None,
                 cache_size: int = 16, tile: int = 128):
        import jax

        from repro import optim
        from repro.kernels.segment_mm import default_interpret

        scheme = getattr(cfg, "grad_compression", "none")
        if scheme not in _SCHEMES:
            raise ValueError(
                f"grad_compression must be one of {_SCHEMES}, got {scheme!r}"
            )
        if agg_impl == "auto":
            agg_impl = "xla" if default_interpret() else "pallas"
        if agg_impl not in ("pallas", "xla"):
            raise ValueError(f"unknown agg_impl {agg_impl!r}")

        from repro.models.gnn import sage

        self.graph = graph
        self.mcfg = sage_config(graph)
        self.tile = int(tile)
        self.agg_impl = agg_impl
        self.scheme = scheme
        self.topk_frac = float(getattr(cfg, "topk_frac", 0.05))
        self.clock = clock or time.perf_counter
        self.params, _ = sage.init(jax.random.PRNGKey(cfg.seed), self.mcfg)
        self.opt = optim.adamw(3e-3)  # greenlint: literal-ok — must match
        # the modeled lane's _init_model lr exactly; plumbing a config
        # field only one lane reads would let the twins drift
        self.opt_state = self.opt.init(self.params)
        self.error = gc.init_error_feedback(self.params)
        self.sync_wire_bytes = float(
            gc.wire_bytes(self.params, scheme, self.topk_frac)
        )
        self.labels_np = np.asarray(graph.labels)

        self._jit = jax.jit(self._step_fn)
        self._fwd_jit = jax.jit(self._forward)
        self._exec: dict = {}            # shape signature -> AOT executable
        self._prep: OrderedDict = OrderedDict()   # mb id -> PreparedBatch
        self._cache_size = int(cache_size)

        self.losses: list[float] = []
        self.step_s: list[float] = []
        self.step_edges: list[int] = []
        self.compile_s = 0.0
        self.n_compiles = 0
        self.parity_max_diff: float | None = None
        self._parity_tol = 2e-3

    # ------------------------------------------------------------ prepare
    def prepare(self, mb, key=None):
        """Block-sparse conversion + pow2 bucketing for one mini-batch.

        Returns ``(layers, x_rows, n_edges, sig)``; cached per ``key``
        (the worker passes ``(epoch, step)``) in a bounded LRU so repeat
        visits inside a rebuild window skip the numpy conversion.
        """
        if key is not None and key in self._prep:
            self._prep.move_to_end(key)
            return self._prep[key]
        prep = self._prepare(mb)
        if key is not None:
            self._prep[key] = prep
            while len(self._prep) > self._cache_size:
                self._prep.popitem(last=False)
        return prep

    def _prepare(self, mb):
        import jax.numpy as jnp

        from repro.kernels.segment_mm import to_block_sparse

        t = self.tile
        layers = []
        n_edges = 0
        n_src_rows = _bucket(-(-len(mb.blocks[0].src_nodes) // t)) * t
        src_rows = n_src_rows
        for i, blk in enumerate(mb.blocks):
            n_dst_true = len(blk.dst_nodes)
            n_dst_blocks = _bucket(-(-n_dst_true // t))
            n_dst_pad = n_dst_blocks * t
            w = blk.edge_mask.astype(np.float32)
            rows, cols, blocks, ndb, n_src_pad = to_block_sparse(
                blk.edge_src, blk.edge_dst, n_dst_pad, src_rows, t, t, w
            )
            assert n_src_pad == src_rows and ndb == n_dst_blocks
            nbp = _bucket(len(rows))
            if nbp > len(rows):
                pad = nbp - len(rows)
                # padding blocks stay zero and point at the last row-block
                # (rows stay sorted; they accumulate nothing)
                rows = np.concatenate(
                    [rows, np.full(pad, ndb - 1, np.int32)]
                )
                cols = np.concatenate([cols, np.zeros(pad, np.int32)])
                blocks = np.concatenate(
                    [blocks, np.zeros((pad, t, t), np.float32)]
                )
            indeg = np.bincount(
                blk.edge_dst[blk.edge_mask], minlength=n_dst_pad
            ).astype(np.float32)
            dst_pos = np.zeros(n_dst_pad, np.int32)
            dst_pos[:n_dst_true] = blk.dst_pos
            layer = {
                "rows": jnp.asarray(rows),
                "cols": jnp.asarray(cols),
                "blocks": jnp.asarray(blocks),
                "counts": jnp.asarray(np.maximum(indeg, 1.0)[:, None]),
                "dst_pos": jnp.asarray(dst_pos),
            }
            if i == len(mb.blocks) - 1:
                labels = np.zeros(n_dst_pad, self.labels_np.dtype)
                labels[:n_dst_true] = self.labels_np[blk.dst_nodes]
                lmask = np.zeros(n_dst_pad, np.float32)
                lmask[:n_dst_true] = blk.dst_mask.astype(np.float32)
                layer["labels"] = jnp.asarray(labels)
                layer["lmask"] = jnp.asarray(lmask)
            layers.append(layer)
            n_edges += int(blk.edge_mask.sum())
            src_rows = n_dst_pad
        return tuple(layers), n_src_rows, n_edges

    def pad_input(self, x_in: np.ndarray, x_rows: int) -> np.ndarray:
        x = np.zeros((x_rows, self.mcfg.d_in), np.float32)
        x[: len(x_in)] = x_in
        return x

    # ------------------------------------------------------------ forward
    def _aggregate(self, layer, h):
        import jax.numpy as jnp

        from repro.kernels.segment_mm import block_spmm_xla
        from repro.kernels.segment_mm.kernel import block_spmm_kernel

        t = self.tile
        n_dst_blocks = layer["counts"].shape[0] // t
        f = h.shape[1]
        if self.agg_impl == "pallas":
            f_pad = -(-f // t) * t
            hp = h
            if f_pad != f:
                hp = jnp.zeros((h.shape[0], f_pad), h.dtype).at[:, :f].set(h)
            y = block_spmm_kernel(
                layer["rows"], layer["cols"], layer["blocks"], hp,
                n_dst_blocks, tn=t, tm=t, tf=t,
            )[:, :f]
        else:
            y = block_spmm_xla(
                layer["rows"], layer["cols"], layer["blocks"], h,
                n_dst_blocks, tn=t, tm=t,
            )
        return y / layer["counts"]

    def _forward(self, params, x_pad, layers):
        """Block-path SAGE forward over prepared layers (padded rows)."""
        import jax

        h = x_pad
        for i, layer in enumerate(layers):
            lp = params[f"layer_{i}"]
            agg = self._aggregate(layer, h)
            h_dst_self = h[layer["dst_pos"]]
            h_new = h_dst_self @ lp["w_self"] + agg @ lp["w_neigh"] + lp["b"]
            if i < len(layers) - 1:
                h_new = jax.nn.relu(h_new)
            h = h_new
        return h

    def _step_fn(self, params, opt_state, error, x_pad, layers):
        import jax

        from repro import optim
        from repro.models.gnn.common import cross_entropy

        last = layers[-1]

        def loss_fn(p):
            logits = self._forward(p, x_pad, layers)
            return cross_entropy(logits, last["labels"], last["lmask"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if self.scheme == "int8":
            grads, error = gc.compress_int8(grads, error)
        elif self.scheme == "topk":
            grads, error = gc.compress_topk(grads, error, self.topk_frac)
        upd, opt_state = self.opt.update(grads, opt_state, params)
        return optim.apply_updates(params, upd), opt_state, error, loss

    # --------------------------------------------------------------- step
    def step(self, mb, x_in: np.ndarray, key=None) -> float:
        """One measured forward/backward/optimizer step.

        ``x_in`` are the resolved feature rows for ``mb.input_nodes``.
        Returns the measured wall seconds of the compiled step (AOT
        compilation on a new shape bucket is excluded and accounted in
        ``compile_s``). Loss/edge-count/timing streams accumulate on the
        engine for calibration and reporting.
        """
        import jax
        import jax.numpy as jnp

        layers, x_rows, n_edges = self.prepare(mb, key)
        if self.parity_max_diff is None:
            self.check_parity(mb, x_in, _prep=(layers, x_rows))
        x_pad = self.pad_input(np.asarray(x_in, np.float32), x_rows)
        args = (self.params, self.opt_state, self.error, jnp.asarray(x_pad),
                layers)
        sig = (x_pad.shape,) + tuple(
            (l["rows"].shape[0], l["counts"].shape[0]) for l in layers
        )
        if sig not in self._exec:
            t0 = self.clock()
            self._exec[sig] = self._jit.lower(*args).compile()
            self.compile_s += self.clock() - t0
            self.n_compiles += 1
        t0 = self.clock()
        out = self._exec[sig](*args)
        jax.block_until_ready(out)
        dt = self.clock() - t0
        self.params, self.opt_state, self.error, loss = out
        self.losses.append(float(loss))
        self.step_s.append(float(dt))
        self.step_edges.append(int(n_edges))
        return float(dt)

    # ------------------------------------------------------------- parity
    def check_parity(self, mb, x_in: np.ndarray, tol: float | None = None,
                     _prep=None):
        """Assert block-path forward == scatter reference on this batch.

        The reference is ``sage.apply_blocks`` (per-edge gather +
        ``common.scatter_sum``/mean) on the UNPADDED blocks; the block
        path must agree on every valid dst row within float-accumulation
        tolerance (summation order differs between the two).
        """
        import jax.numpy as jnp

        from repro.models.gnn import sage

        tol = self._parity_tol if tol is None else tol
        if _prep is None:
            layers, x_rows, _ = self.prepare(mb)
        else:
            layers, x_rows = _prep
        x_pad = self.pad_input(np.asarray(x_in, np.float32), x_rows)
        got = self._fwd_jit(self.params, jnp.asarray(x_pad), layers)
        ref_blocks = [
            {
                "edge_src": jnp.asarray(b.edge_src),
                "edge_dst": jnp.asarray(b.edge_dst),
                "edge_mask": jnp.asarray(b.edge_mask),
                "dst_pos": jnp.asarray(b.dst_pos),
            }
            for b in mb.blocks
        ]
        ref = sage.apply_blocks(
            self.params, self.mcfg,
            jnp.asarray(np.asarray(x_in, np.float32)), ref_blocks,
        )
        n = ref.shape[0]
        valid = np.asarray(mb.blocks[-1].dst_mask, bool)
        diff = np.abs(np.asarray(got)[:n] - np.asarray(ref))[valid]
        self.parity_max_diff = float(diff.max()) if diff.size else 0.0
        if self.parity_max_diff > tol:
            raise AssertionError(
                f"block-path/scatter parity violated: max |diff| "
                f"{self.parity_max_diff:.3e} > {tol:.0e} "
                f"(agg_impl={self.agg_impl})"
            )
        return self.parity_max_diff

    # ---------------------------------------------------------- reporting
    def model_eval(self, graph) -> float:
        from repro.train import gnn_trainer as gt

        return gt._model_eval({"params": self.params, "cfg": self.mcfg},
                              graph)

    def calibration_samples(self) -> tuple[np.ndarray, np.ndarray]:
        """(n_edges, step_s) pairs for ``calibration.calibrate_compute``."""
        return (
            np.asarray(self.step_edges, np.float64),
            np.asarray(self.step_s, np.float64),
        )

    def report(self) -> dict:
        return {
            "n_steps": len(self.step_s),
            "losses": list(self.losses),
            "step_s": list(self.step_s),
            "step_edges": list(self.step_edges),
            "compile_s": self.compile_s,
            "n_compiles": self.n_compiles,
            "agg_impl": self.agg_impl,
            "grad_compression": self.scheme,
            "sync_wire_bytes": self.sync_wire_bytes,
            "parity_max_diff": self.parity_max_diff,
        }
