"""End-to-end policy pipeline: calibrate -> train Double-DQN -> deploy.

This is the paper's three-phase flow (Section IV): Algorithm-1 calibration
against the *trace-driven trainer* (our "cluster"), simulator training with
domain randomization, and a deployable q_fn for the AdaptiveController.
Artifacts (theta_sim + 400KB-scale qnet checkpoint) are cached on disk so
tests/benchmarks share one trained policy.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import envs as envs_lib
from repro.core import calibration as cal
from repro.core import cost_model as cm
from repro.core import dqn as dqn_lib
from repro.core import queue_sim
from repro.core import simulator as sim

# Named training environments (the unified env protocol lives in
# ``repro.envs``: any module with reset(cfg, key, params) /
# step(cfg, state, action)).
ENVS = envs_lib.ENVS

ARTIFACT_DIR = os.environ.get(
    "REPRO_ARTIFACTS", os.path.join(os.path.dirname(__file__), "../../../.artifacts")
)


def calibrate_from_bundle(bundle, run_cfg) -> tuple[cm.CostModelParams, dict]:
    """Algorithm 1 against the trace-driven trainer.

    Phase 2: replay the real remote-access trace through the windowed cache
    for a W sweep (hit-rate + rebuild fits), then fit the effective per-node
    miss latency from a (W, delta) grid of measured stall times.
    """
    import dataclasses as dc

    from repro.graph.features import ShardedFeatureStore
    from repro.train import gnn_trainer as gt

    graph, owner, traces, _ = bundle
    store = ShardedFeatureStore(graph.features, owner, 0, run_cfg.n_parts)
    owner_idx = store.owner_index(np.arange(graph.n_nodes))
    remote_trace = [
        store.remote_ids_of(t) for ep in traces[:4] for t in ep
    ]
    capacity = int(run_cfg.cache_frac * graph.n_nodes)
    base = cm.CostModelParams(feature_bytes=store.bytes_per_row)
    theta, diag = cal.calibrate(
        remote_trace, owner_idx, run_cfg.n_parts - 1, capacity, base=base
    )

    # ---- Phase 2b: effective miss latency from a (W, delta) stall grid ----
    r_mean = float(np.mean([len(t) for t in remote_trace]))
    num, den = 0.0, 0.0
    grid = []
    # the stall grid replays the bundle's presampled epochs; short bundles
    # (small --steps sweeps) may carry fewer than the 3 the grid prefers
    grid_epochs = min(3, len(traces))
    for delta in (0.0, 10.0, 20.0):
        for w in (4, 16, 64):
            r = gt.run(
                dc.replace(
                    run_cfg, method="static_w", static_window=w,
                    congested=delta > 0, fixed_delta_ms=delta or None,
                    n_epochs=grid_epochs, q_fn=None,
                ),
                bundle,
            )
            t_step = r.meter.wall_s / max(r.meter.n_steps, 1)
            stall = max(t_step - float(theta.t_base), 0.0)
            h = float(r.hit_rate_per_epoch.mean())
            sigma = float(cm.sigma_from_delta(theta, delta))
            factor = r_mean * (1.0 - h) * sigma
            num += stall * factor
            den += factor * factor
            grid.append({"w": w, "delta": delta, "stall": stall, "h": h})
    t_miss0 = num / max(den, 1e-12)
    theta = theta.replace(t_miss0=max(t_miss0, 1e-6), remote_nodes=r_mean)
    diag["miss_grid"] = grid
    diag["t_miss0"] = t_miss0
    return theta, diag


def calibrate_table_from_bundle(bundle, run_cfg) -> "table_sim.TableParams":
    """Tabular Phase-2 calibration (see core/table_sim.py): replay the real
    trace through the real cache per (W, allocation) pair."""
    from repro.core import table_sim
    from repro.graph.features import ShardedFeatureStore

    graph, owner, traces, _ = bundle
    store = ShardedFeatureStore(graph.features, owner, 0, run_cfg.n_parts)
    owner_idx = store.owner_index(np.arange(graph.n_nodes))
    remote_trace = [store.remote_ids_of(t) for ep in traces[:3] for t in ep]
    capacity = int(run_cfg.cache_frac * graph.n_nodes)
    tables = table_sim.measure_table(
        remote_trace, owner_idx, capacity, run_cfg.n_parts - 1
    )
    base = cm.CostModelParams()
    return table_sim.make_table_params(
        tables,
        t_base=float(base.t_base),
        feature_bytes=store.bytes_per_row,
        slack=run_cfg.prefetch_depth * float(base.t_base),
    )


def make_params_pool(thetas: list) -> cm.CostModelParams:
    """Stack calibrated parameter sets along a leading axis (episode pool)."""
    return jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]),
        *thetas,
    )


def resolve_env(env, params_pool=None):
    """Resolve an env spec (name, module, or None) to an env module.

    Thin delegate to :func:`repro.envs.resolve_env` (kept here because
    callers historically imported it from the policy pipeline). Names:
    ``"analytic"`` (core.simulator, parametric archetypes), ``"table"``
    (core.table_sim, trace-calibrated tables), ``"queue"``
    (core.queue_sim, scenario-conditioned fluid fabric), ``"cluster"``
    (envs.cluster_sim, the P-requester twin with emergent congestion).
    ``None`` keeps the legacy behavior of inferring from the pool's
    parameter type.
    """
    return envs_lib.resolve_env(env, params_pool)


def train_policy(
    params_pool,
    iterations: int = 40_000,
    n_envs: int = 64,
    seed: int = 0,
    env=None,
    steps_per_epoch: int = 32,   # training epoch granularity; the
                                 # batches_remaining observation is
                                 # normalized to [0, 1], so deployment may
                                 # use a different epoch length (the
                                 # gauntlet trains at the paper's 30x32
                                 # horizon and evaluates shorter runs)
    n_epochs: int = 30,
    scenario_pool=None,          # queue/cluster env: registry specs/codes
    n_owners: int | None = None,  # remote owners per worker (n_parts - 1,
                                 # default 3); sizes the obs/action
                                 # spaces, so cluster sweeps at P != 4
                                 # train per-P policies
    n_workers: int | None = None,  # cluster env: cluster size P (n_parts;
                                 # implies n_owners = P - 1)
    cluster_kwargs: dict | None = None,  # extra ClusterEnvConfig fields
                                 # (cluster_pool, peer_pool, sync, ...)
) -> dict:
    from repro.envs import cluster_sim

    env = resolve_env(env, params_pool)
    if scenario_pool is not None and env not in (queue_sim, cluster_sim):
        raise ValueError(
            "scenario_pool only applies to the queue/cluster envs; the "
            "analytic/table envs draw from the legacy archetype schedule"
        )
    if n_workers is not None and env is not cluster_sim:
        raise ValueError("n_workers only applies to the cluster env")
    if scenario_pool is not None and not scenario_pool:
        raise ValueError("scenario_pool is empty; pass None for the "
                         "default training pool")
    if scenario_pool is not None:
        scenario_pool = tuple(
            queue_sim.code_for(s) if isinstance(s, str) else int(s)
            for s in scenario_pool
        )
    if env is not cluster_sim and n_owners is None:
        n_owners = 3
    if env is cluster_sim:
        if n_workers is None:
            n_workers = (3 if n_owners is None else n_owners) + 1
        elif n_owners is not None and n_owners != n_workers - 1:
            raise ValueError(
                f"n_workers={n_workers} implies n_owners="
                f"{n_workers - 1}, got n_owners={n_owners}"
            )
        n_owners = n_workers - 1
        kw = dict(cluster_kwargs or {})
        if scenario_pool is not None:
            kw["scenario_pool"] = scenario_pool
        env_cfg = cluster_sim.ClusterEnvConfig(
            n_parts=n_workers, steps_per_epoch=steps_per_epoch,
            n_epochs=n_epochs, **kw,
        )
    elif env is queue_sim:
        if scenario_pool is None:
            scenario_pool = queue_sim.default_training_pool()
        env_cfg = queue_sim.QueueEnvConfig(
            n_owners=n_owners, steps_per_epoch=steps_per_epoch,
            n_epochs=n_epochs, scenario_pool=scenario_pool,
        )
    else:
        env_cfg = sim.EnvConfig(
            n_owners=n_owners, schedule=0, steps_per_epoch=steps_per_epoch,
            n_epochs=n_epochs,
        )
    # warmup scales down with tiny budgets (smoke tests) so gradient steps
    # always run: a fixed 2000 would exceed iterations * n_envs inserted
    # transitions and silently return an untrained network
    min_replay = min(2_000, max(iterations * n_envs // 4, 64))
    cfg = dqn_lib.DQNConfig(
        n_envs=n_envs, iterations=iterations, min_replay=min_replay,
        eps_decay_iters=max(iterations // 3, 1), seed=seed,
        n_owners=n_owners,
    )
    return dqn_lib.train_dqn(cfg, env_cfg, params_pool, env=env)


def get_or_train_policy(
    params_pool,
    name: str = "qnet",
    iterations: int = 40_000,
    force: bool = False,
    env=None,
    **train_kw,
):
    """Returns (q_fn, qnet). Caches the trained network under .artifacts/.

    ``env`` selects the training environment (see :func:`resolve_env`);
    named envs get per-env artifacts (``<name>_<env>.npz``) so checkpoints
    trained on different dynamics never collide. The cluster env
    additionally suffixes the cluster size (``<name>_cluster_p<P>.npz``,
    from ``n_workers=P``) because its obs/action spaces — and the
    congestion it was trained on — are per-P. Checkpoints are
    reproducible local artifacts, not tracked files: a missing or
    unreadable .npz (fresh clone, partial write, stale format) silently
    falls through to retraining instead of crashing the caller —
    regenerate explicitly with ``scripts/export_qnet.py``.
    """
    if isinstance(env, str):
        name = f"{name}_{env}"
        if env == "cluster":
            n_workers = train_kw.get("n_workers") or (
                (train_kw.get("n_owners") or 3) + 1
            )
            name = f"{name}_p{int(n_workers)}"
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"{name}.npz")
    qnet = None
    if os.path.exists(path) and not force:
        try:
            qnet = dqn_lib.load_qnet(path)
        except (OSError, ValueError, KeyError) as e:
            # corrupt/stale/truncated artifact: log and rebuild it. Anything
            # else (e.g. a bug in load_qnet itself) propagates.
            logging.getLogger(__name__).warning(
                "[policy] could not load %s (%r); retraining", path, e
            )
    if qnet is None:
        result = train_policy(
            params_pool, iterations=iterations, env=env, **train_kw
        )
        qnet = result["qnet"]
        dqn_lib.save_qnet(path, qnet)
        meta = {
            "iterations": iterations,
            "env": env if isinstance(env, str) else "auto",
            "episodes": int(result["episodes"]),
            "grad_steps": int(result.get("grad_steps", 0)),
            "final_reward": float(
                np.mean(np.asarray(result["metrics"]["reward"])[-200:])
            ),
        }
        with open(os.path.join(ARTIFACT_DIR, f"{name}.json"), "w") as f:
            json.dump(meta, f)

    fwd = jax.jit(dqn_lib.q_forward)

    def q_fn(state: np.ndarray) -> np.ndarray:
        return np.asarray(fwd(qnet, jnp.asarray(state, jnp.float32)))

    return q_fn, qnet
