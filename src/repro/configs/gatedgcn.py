"""GatedGCN [arXiv:2003.00982]: 16 layers, d_hidden=70, gated aggregation."""
from repro.configs.registry import ArchDef
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn.gatedgcn import GatedGCNConfig


def make_config(d_in: int = 100, n_classes: int = 47) -> GatedGCNConfig:
    return GatedGCNConfig(d_in=d_in, d_hidden=70, n_classes=n_classes,
                          n_layers=16)


def make_smoke_config() -> GatedGCNConfig:
    return GatedGCNConfig(d_in=16, d_hidden=12, n_classes=5, n_layers=3)


ARCH = ArchDef(
    arch_id="gatedgcn", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(GNN_SHAPES),
    model_module="repro.models.gnn.gatedgcn",
)
