"""tinyllama-1.1b [arXiv:2401.02385].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000 (llama2-arch small).
kv=4 < 16-way TP -> cache shards its sequence dimension.
"""
from repro.configs.registry import ArchDef
from repro.configs.shapes import LM_SHAPES
from repro.models.lm.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="tinyllama-1.1b", n_layers=22, d_model=2048, n_heads=32,
        n_kv_heads=4, d_head=64, d_ff=5_632, vocab=32_000,
        attn_type="gqa", rope_theta=10_000.0, grad_accum=2, dtype="bfloat16",
        loss_chunk=1_024,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="tinyllama-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=160, vocab=256, attn_type="gqa",
        dtype="float32", remat=False,
    )


ARCH = ArchDef(
    arch_id="tinyllama-1.1b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(LM_SHAPES),
    rule_overrides={"heads": "model", "kv_heads": None, "cache_seq": "model"},
    model_module="repro.models.lm.transformer",
)
