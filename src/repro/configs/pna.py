"""PNA [arXiv:2004.05718]: 4 layers, d_hidden=75, aggregators
mean-max-min-std, scalers identity-amplification-attenuation."""
from repro.configs.registry import ArchDef
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn.pna import PNAConfig


def make_config(d_in: int = 100, n_classes: int = 47) -> PNAConfig:
    return PNAConfig(d_in=d_in, d_hidden=75, n_classes=n_classes, n_layers=4)


def make_smoke_config() -> PNAConfig:
    return PNAConfig(d_in=16, d_hidden=16, n_classes=5, n_layers=2)


ARCH = ArchDef(
    arch_id="pna", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(GNN_SHAPES),
    model_module="repro.models.gnn.pna",
)
