"""deepseek-v2-236b [arXiv:2405.04434].

60L d_model=5120 128H, MLA (kv_lora=512, q_lora=1536, d_nope=128, d_rope=64,
d_v=128), vocab=102400, MoE 160 routed top-6 + 2 shared (d_ff_expert=1536),
first layer dense d_ff=12288.
"""
from repro.configs.registry import ArchDef
from repro.configs.shapes import LM_SHAPES
from repro.models.lm.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b", n_layers=60, d_model=5120,
        n_heads=128, n_kv_heads=128, d_head=128, d_ff=12_288, vocab=102_400,
        attn_type="mla", q_lora=1_536, kv_lora=512, d_nope=128, d_rope=64,
        d_v=128, rope_theta=10_000.0,
        moe=True, n_experts=160, top_k=6, n_shared=2, d_ff_expert=1_536,
        first_k_dense=1, grad_accum=8, dtype="bfloat16", loss_chunk=512,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=160, vocab=256, attn_type="mla",
        q_lora=32, kv_lora=24, d_nope=16, d_rope=8, d_v=16,
        moe=True, n_experts=8, top_k=2, n_shared=2, d_ff_expert=32,
        first_k_dense=1, dtype="float32", remat=False,
    )


ARCH = ArchDef(
    arch_id="deepseek-v2-236b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(LM_SHAPES),
    rule_overrides={"heads": "model", "kv_lora": "model",
                    "q_lora": None, "cache_seq": None},
    model_module="repro.models.lm.transformer",
)
