"""MACE [arXiv:2206.07697]: 2 layers, mul=128, l_max=2, correlation order 3,
8 RBF, E(3)-ACE product basis. Non-geometric shapes use synthesized 3-D
positions (DESIGN.md section 4)."""
from repro.configs.registry import ArchDef
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn.mace import MACEConfig


def make_config(edge_chunk: int = 0) -> MACEConfig:
    return MACEConfig(n_species=32, d_hidden=128, n_layers=2, l_max=2,
                      correlation=3, n_rbf=8, cutoff=5.0,
                      edge_chunk=edge_chunk)


def make_smoke_config() -> MACEConfig:
    return MACEConfig(n_species=8, d_hidden=8, n_layers=2, l_max=2, n_rbf=4,
                      correlation=3)


ARCH = ArchDef(
    arch_id="mace", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(GNN_SHAPES),
    model_module="repro.models.gnn.mace",
)
