"""minicpm3-4b [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H d_ff=6400 vocab=73448, MLA (kv_lora=256, q_lora=768,
d_nope=64, d_rope=32, d_v=64). 40 heads don't divide the 16-way model axis:
attention TP is disabled (heads replicated); TP lives on d_ff (6400/16) and
the latent dims (256/16); vocab padded 73448 -> 73472 for the 16-way shard.
"""
from repro.configs.registry import ArchDef
from repro.configs.shapes import LM_SHAPES
from repro.models.lm.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40,
        n_kv_heads=40, d_head=64, d_ff=6_400, vocab=73_448,
        vocab_pad_to=73_472,
        attn_type="mla", q_lora=768, kv_lora=256, d_nope=64, d_rope=32,
        d_v=64, rope_theta=10_000.0, grad_accum=4, dtype="bfloat16", loss_chunk=512,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="minicpm3-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=160, vocab=250, vocab_pad_to=256,
        attn_type="mla", q_lora=32, kv_lora=24, d_nope=16, d_rope=8, d_v=16,
        dtype="float32", remat=False,
    )


ARCH = ArchDef(
    arch_id="minicpm3-4b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(LM_SHAPES),
    rule_overrides={"heads": None, "kv_lora": "model", "q_lora": None,
                    "cache_seq": None},
    model_module="repro.models.lm.transformer",
    notes="40 heads % 16 != 0: attention TP replicated; TP on mlp + latents",
)
