"""FM [Rendle ICDM'10]: 39 sparse fields, embed_dim=10, 2-way interactions
via the O(nk) sum-square trick. ~38.8M-row Criteo-like table padded to a
multiple of 256 for (data x model) row sharding."""
from repro.configs.registry import ArchDef
from repro.configs.shapes import FM_SHAPES
from repro.models.recsys.fm import CRITEO_VOCABS, FMConfig


def make_config() -> FMConfig:
    raw = sum(CRITEO_VOCABS)
    pad = -(-raw // 256) * 256
    return FMConfig(n_fields=39, embed_dim=10, pad_rows_to=pad)


def make_smoke_config() -> FMConfig:
    return FMConfig(n_fields=6, embed_dim=4, vocab_sizes=(10, 20, 5, 8, 12, 7))


ARCH = ArchDef(
    arch_id="fm", family="recsys",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(FM_SHAPES),
    model_module="repro.models.recsys.fm",
)
