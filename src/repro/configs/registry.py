"""Central architecture registry.

Each arch module defines an ``ARCH: ArchDef`` with its exact assigned
config, a reduced smoke config, its shape set, and (optionally) per-arch
sharding-rule overrides (e.g. head counts that don't divide the TP axis).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str                      # "lm" | "gnn" | "recsys"
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: tuple                    # shape names valid for this arch
    rule_overrides: dict = dataclasses.field(default_factory=dict)
    model_module: str = ""           # import path of the model implementation
    notes: str = ""


_MODULES = {
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1p1b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "pna": "repro.configs.pna",
    "mace": "repro.configs.mace",
    "gatedgcn": "repro.configs.gatedgcn",
    "nequip": "repro.configs.nequip",
    "fm": "repro.configs.fm",
    "greendygnn-sage": "repro.configs.greendygnn_sage",
}

ARCHS = tuple(_MODULES)


def get_arch(arch_id: str) -> ArchDef:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).ARCH
