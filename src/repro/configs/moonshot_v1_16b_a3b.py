"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) d_ff_expert=1408 vocab=163840,
MoE 64 routed top-6 + 2 shared, first layer dense (DeepSeek-V3-style arch).
"""
from repro.configs.registry import ArchDef
from repro.configs.shapes import LM_SHAPES
from repro.models.lm.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048,
        n_heads=16, n_kv_heads=16, d_head=128, d_ff=11_264, vocab=163_840,
        attn_type="gqa", rope_theta=50_000.0,
        moe=True, n_experts=64, top_k=6, n_shared=2, d_ff_expert=1_408,
        first_k_dense=1, grad_accum=4, dtype="bfloat16", loss_chunk=512,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="moonshot-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=160, vocab=256, attn_type="gqa",
        moe=True, n_experts=8, top_k=2, n_shared=2, d_ff_expert=32,
        first_k_dense=1, dtype="float32", remat=False,
    )


ARCH = ArchDef(
    arch_id="moonshot-v1-16b-a3b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(LM_SHAPES),
    rule_overrides={"heads": "model", "kv_heads": "model",
                    "cache_seq": None},
    model_module="repro.models.lm.transformer",
)
