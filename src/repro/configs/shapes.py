"""Assigned input shapes per family (the x-axis of the 40-cell matrix)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = {
    "train_4k": LMShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": LMShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": LMShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": LMShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str  # "full_graph" | "minibatch" | "molecule"
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanouts: tuple = ()
    batch_graphs: int = 0
    atoms_per_graph: int = 0
    edges_per_graph: int = 0


GNN_SHAPES = {
    "full_graph_sm": GNNShape(
        "full_graph_sm", "full_graph", n_nodes=2_708, n_edges=10_556,
        d_feat=1_433,
    ),
    "minibatch_lg": GNNShape(
        "minibatch_lg", "minibatch", n_nodes=232_965, n_edges=114_615_892,
        d_feat=602, batch_nodes=1_024, fanouts=(15, 10),
    ),
    "ogb_products": GNNShape(
        "ogb_products", "full_graph", n_nodes=2_449_029, n_edges=61_859_140,
        d_feat=100,
    ),
    "molecule": GNNShape(
        "molecule", "molecule", batch_graphs=128, atoms_per_graph=30,
        edges_per_graph=64,
    ),
}


@dataclasses.dataclass(frozen=True)
class FMShape:
    name: str
    kind: str  # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


FM_SHAPES = {
    "train_batch": FMShape("train_batch", "train", 65_536),
    "serve_p99": FMShape("serve_p99", "serve", 512),
    "serve_bulk": FMShape("serve_bulk", "serve", 262_144),
    "retrieval_cand": FMShape("retrieval_cand", "retrieval", 1, 1_000_000),
}
