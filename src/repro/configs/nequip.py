"""NequIP [arXiv:2101.03164]: 5 layers, mul=32, l_max=2, 8 RBF, cutoff 5,
E(3) tensor-product message passing. Non-geometric shapes use synthesized
3-D positions (DESIGN.md section 4)."""
from repro.configs.registry import ArchDef
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn.nequip import NequIPConfig


def make_config(edge_chunk: int = 0) -> NequIPConfig:
    return NequIPConfig(n_species=32, d_hidden=32, n_layers=5, l_max=2,
                        n_rbf=8, cutoff=5.0, edge_chunk=edge_chunk)


def make_smoke_config() -> NequIPConfig:
    return NequIPConfig(n_species=8, d_hidden=8, n_layers=2, l_max=2, n_rbf=4)


ARCH = ArchDef(
    arch_id="nequip", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(GNN_SHAPES),
    model_module="repro.models.gnn.nequip",
)
