"""Architecture registry: ``--arch <id>`` selects one of these."""
from repro.configs.registry import ARCHS, ArchDef, get_arch  # noqa: F401
