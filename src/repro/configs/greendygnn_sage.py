"""The paper's own training model: 2-layer GraphSAGE, 16 hidden units,
fan-out {10, 25} (Section VI-A), run under the GreenDyGNN pipeline."""
from repro.configs.registry import ArchDef
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn.sage import SageConfig


def make_config(d_in: int = 602, n_classes: int = 41) -> SageConfig:
    return SageConfig(d_in=d_in, d_hidden=16, n_classes=n_classes, n_layers=2)


def make_smoke_config() -> SageConfig:
    return SageConfig(d_in=16, d_hidden=8, n_classes=5, n_layers=2)


ARCH = ArchDef(
    arch_id="greendygnn-sage", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(GNN_SHAPES),
    model_module="repro.models.gnn.sage",
)
