"""qwen3-1.7b [hf:Qwen/Qwen3-1.7B family].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, qk-norm.
kv=8 does not divide the 16-way model axis -> the KV cache shards its
sequence dimension instead (rule override cache_seq -> model).
"""
from repro.configs.registry import ArchDef
from repro.configs.shapes import LM_SHAPES
from repro.models.lm.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=8, d_head=128, d_ff=6_144, vocab=151_936,
        attn_type="gqa", qk_norm=True, rope_theta=1_000_000.0,
        grad_accum=2, dtype="bfloat16", loss_chunk=512,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen3-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=160, vocab=256, attn_type="gqa", qk_norm=True,
        dtype="float32", remat=False,
    )


ARCH = ArchDef(
    arch_id="qwen3-1.7b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=tuple(LM_SHAPES),
    rule_overrides={"heads": "model", "kv_heads": None, "cache_seq": "model"},
    model_module="repro.models.lm.transformer",
)
