"""Cluster-twin training environment: P requesters over shared owner NICs.

``core/queue_sim.py`` closed the train/eval gap for a SINGLE requester: a
fluid twin of the event fabric whose congestion is injected by background
processes. But since PR 4 the evaluation is ``train.cluster.run_cluster``
— P live trainers over one requester-aware fabric — where the headline
congestion is *emergent*: incast at a hot feature owner, peer rebuild
storms occupying shared NICs, straggler feedback through the per-step
gradient-sync barrier. A policy trained on queue_sim has never seen any
of that. This module is the P-requester twin:

  * **shared owner NICs** — the ego rank's per-owner link queues are fed
    by P arrival processes: its own per-step miss fetches and
    window-boundary rebuild bulk fetches (FIFO behind each other at the
    calibrated ``(1-u)/(1+(gamma_c/beta)*delta)`` service law, exactly as
    in queue_sim) PLUS the miss traffic and synchronized rebuild storms of
    ``n_peers`` scripted co-trained ranks. Peer work queues FIFO *ahead*
    of the ego's new arrivals, so a peer's window rebuild physically
    delays the ego's fine-grained misses — the rebuild-interference
    mechanism of the eval fabric;
  * **scripted peer models** — peers run a static-W=16 or a
    congestion-reactive ("greendygnn-like", window shrinks with observed
    sigma) cache policy; the per-episode mix is domain-randomized. Peer
    rank ``i+1`` owns global partition ``i+1``, which is the ego's owner
    slot ``i`` under the shared ``net.fabric.owner_links`` mapping — so a
    peer never fetches from its own NIC and every other NIC receives its
    per-owner share;
  * **lockstep barrier coupling** — each step ends in the gradient sync
    the cluster driver charges: the ego waits for the slowest live rank
    (compute-scaled stragglers, congestion-stalled peers) and then pays
    the ring-collective cost (the jnp twin of
    ``distributed.collectives.ring_collective_cost``), with
    EnergyMeter.record_sync-faithful energy (GPU idles through the wait,
    CPU pays base power plus RPC protocol work for the collective);
  * **per-rank heterogeneity + demand skew** — episodes sample the same
    emergent-scenario archetypes ``benchmarks/cluster_sweep.py``
    evaluates (``clean`` / ``hot_owner`` / ``slow_worker`` /
    ``demand_skew``) with domain-randomized severities, on top of the
    full injected-overlay pool of the ``ScenarioRegistry`` names
    (queue_sim's scenario codes), plus domain randomization over the
    number of live peers (the "P axis": contention from 0 to
    ``n_parts - 1`` co-trained ranks);
  * **deployment-faithful observations** — identical to queue_sim's
    (Eq. 8 sigma estimator with the config-plumbed clamp, exposed-wait
    fractions, +-3% telemetry noise); the observed t_step/f_miss include
    the sync wait, exactly what the deployed controller's meter deltas
    contain in a cluster run.

Reduction contract: with ``peer_pool=(0,)`` and ``cluster_pool=(0,)``
(no peers, no heterogeneity) every added term is exactly zero/one and an
episode reproduces ``queue_sim`` trajectories BIT-FOR-BIT (asserted in
``tests/test_cluster_env.py``) — the cluster twin is a strict superset.

The MDP interface is the unified env protocol (``reset(cfg, key, params)``
/ ``step(cfg, state, action)``), so ``dqn.train_dqn`` vmaps thousands of
cluster episodes unchanged; observation/action spaces are sized by
``n_owners = n_parts - 1``, matching the deployed controller at P ranks.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import cost_model as cm
from repro.core import queue_sim as qs

MAX_WINDOW = qs.MAX_WINDOW
REF_W = qs.REF_W
PROP_RTT_S_PER_MS = qs.PROP_RTT_S_PER_MS
ACTIVE_ROWS_SCALE = qs.ACTIVE_ROWS_SCALE
REBUILD_FETCH_FRAC = qs.REBUILD_FETCH_FRAC

# Emergent cluster archetypes — the SAME names benchmarks/cluster_sweep.py
# registers as its emergent scenarios, so training is conditioned on the
# eval vocabulary on this axis too.
CLUSTER_CODES = {
    "clean": 0,
    "hot_owner": 1,
    "slow_worker": 2,
    "demand_skew": 3,
}
N_CLUSTER = len(CLUSTER_CODES)

SYNC_MODES = ("allreduce", "reduce_scatter", "none")
PEER_POLICIES = ("static", "greendygnn", "mixed")


def default_cluster_pool() -> tuple[int, ...]:
    """All four emergent archetypes, uniformly sampled per episode."""
    return tuple(CLUSTER_CODES[n] for n in (
        "clean", "hot_owner", "slow_worker", "demand_skew",
    ))


def cluster_code_for(spec: str) -> int:
    """Map an emergent-scenario name from the cluster sweep to its
    training code (overlay names go through ``queue_sim.code_for``)."""
    name = spec.split(":", 1)[0]
    if name not in CLUSTER_CODES:
        raise KeyError(
            f"no cluster-sim archetype for scenario {spec!r}; "
            f"known: {', '.join(sorted(CLUSTER_CODES))}"
        )
    return CLUSTER_CODES[name]


# ----------------------------------------------------------------- env cfg
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ClusterEnvConfig:
    """Shape of the P-rank cluster the ego trains inside.

    ``n_parts`` is the cluster size P: the ego is rank 0 of ``n_parts``
    partitions and sees ``n_owners = n_parts - 1`` remote owners (the
    ``owner_links`` mapping — a requester skips itself), which sizes the
    observation/action spaces exactly like deployment at P ranks.
    """

    n_parts: int = dataclasses.field(default=4, metadata={"static": True})
    n_epochs: int = dataclasses.field(default=30, metadata={"static": True})
    steps_per_epoch: int = dataclasses.field(
        default=128, metadata={"static": True}
    )
    # injected-overlay pool (queue_sim SCENARIO_CODES values), sampled
    # uniformly per episode — same registry vocabulary as the eval fabric
    scenario_pool: tuple = dataclasses.field(
        default_factory=qs.default_training_pool, metadata={"static": True}
    )
    # emergent-archetype pool (CLUSTER_CODES values), sampled independently
    cluster_pool: tuple = dataclasses.field(
        default_factory=default_cluster_pool, metadata={"static": True}
    )
    # live-peer counts sampled per episode (DR over the contention axis);
    # None = half the mass on the full fleet, rest spread over 0..P-2
    peer_pool: tuple | None = dataclasses.field(
        default=None, metadata={"static": True}
    )
    # scripted peer cache policy: "static" (W=16 uniform), "greendygnn"
    # (window shrinks with observed sigma), or "mixed" (per-episode coin)
    peer_policy: str = dataclasses.field(
        default="mixed", metadata={"static": True}
    )
    slack_steps: float = dataclasses.field(
        default=4.0, metadata={"static": True}
    )
    # per-step gradient sync: payload + ring schedule (collectives twin)
    grad_bytes: float = dataclasses.field(
        default=12480.0, metadata={"static": True}
    )
    sync: str = dataclasses.field(
        default="allreduce", metadata={"static": True}
    )
    # tiered-store pressure twin (see queue_sim: same semantics, read by
    # the SHARED qs.mem_spill / qs._observe helpers; 0 = unlimited and
    # bit-identical to the legacy env)
    mem_budget_frac: float = dataclasses.field(
        default=0.0, metadata={"static": True}
    )
    observe_headroom: bool = dataclasses.field(
        default=False, metadata={"static": True}
    )

    def __post_init__(self):
        if self.n_parts < 2:
            raise ValueError("cluster env needs n_parts >= 2")
        if self.sync not in SYNC_MODES:
            raise ValueError(
                f"unknown sync mode {self.sync!r}; expected {SYNC_MODES}"
            )
        if self.peer_policy not in PEER_POLICIES:
            raise ValueError(
                f"unknown peer policy {self.peer_policy!r}; "
                f"expected {PEER_POLICIES}"
            )

    @property
    def n_owners(self) -> int:
        return self.n_parts - 1

    @property
    def total_steps(self) -> int:
        return self.n_epochs * self.steps_per_epoch

    # greenlint: host-fn — config-time helper, never traced
    def resolved_peer_pool(self) -> tuple[int, ...]:
        if self.peer_pool is not None:
            return tuple(int(p) for p in self.peer_pool)
        # weight the deployed configuration (full fleet) at ~half the mass
        full = self.n_owners
        return (full,) * max(full, 1) + tuple(range(full))


# ---------------------------------------------------------------- scenario
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ClusterScenario:
    """One episode's cluster recipe: injected overlay + emergent factors."""

    base: qs.QueueScenario     # injected-overlay recipe (queue_sim twin)
    cluster_kind: jax.Array    # int32, CLUSTER_CODES value
    n_peers: jax.Array         # int32 live scripted peers (<= n_owners)
    link_scale: jax.Array      # (n_owners,) ego-slot NIC rate multiplier
    own_scale: jax.Array       # ego-partition NIC rate multiplier (peers
                               # fetch from it; the ego never does)
    demand_skew: jax.Array     # (n_owners,) per-owner demand multiplier
                               # relative to uniform (1 = uniform)
    ego_compute: jax.Array     # ego t_base multiplier (>= 1 = straggler)
    peer_compute: jax.Array    # (n_owners,) per-peer t_base multiplier
    peer_reactive: jax.Array   # 1.0 = peers run the reactive policy


def sample_cluster_factors(
    key: jax.Array, code: jax.Array, cfg: ClusterEnvConfig
) -> dict:
    """Domain-randomize one emergent archetype's severity/placement.

    Severities bracket the eval sweep's defaults (hot_owner rate 0.35,
    slow_worker factor 1.5, demand bias ~50%)."""
    n = cfg.n_owners
    ks = jax.random.split(key, 6)
    ones = jnp.ones((n,), jnp.float32)
    one = jnp.asarray(1.0, jnp.float32)
    idx = jnp.arange(n)

    def _clean(_):
        return dict(link_scale=ones, own_scale=one, demand_skew=ones,
                    ego_compute=one, peer_compute=ones)

    def _hot_owner(_):
        # a hot/slow feature server: any of the n_parts NICs, including
        # the ego's own partition (then only peers feel it directly)
        victim = jax.random.randint(ks[0], (), 0, cfg.n_parts)
        rate = jax.random.uniform(ks[1], (), minval=0.25, maxval=0.6)
        link = jnp.where(idx == victim - 1, rate, 1.0)
        return dict(
            link_scale=jnp.where(victim == 0, ones, link),
            own_scale=jnp.where(victim == 0, rate, 1.0),
            demand_skew=ones, ego_compute=one, peer_compute=ones,
        )

    def _slow_worker(_):
        # one straggler rank (possibly the ego itself)
        rank = jax.random.randint(ks[2], (), 0, cfg.n_parts)
        factor = jax.random.uniform(ks[3], (), minval=1.25, maxval=2.0)
        return dict(
            link_scale=ones, own_scale=one, demand_skew=ones,
            ego_compute=jnp.where(rank == 0, factor, 1.0),
            peer_compute=jnp.where(idx == rank - 1, factor, 1.0),
        )

    def _demand_skew(_):
        # one partition owns a disproportionate share of globally-hot
        # nodes: every rank directs `frac` of its remote demand there
        if n == 1:            # a single owner cannot be skewed against
            return _clean(None)
        hot = jax.random.randint(ks[4], (), 0, n)
        frac = jax.random.uniform(ks[5], (), minval=0.35, maxval=0.65)
        skew_hot = frac * n
        skew_rest = (1.0 - frac) * n / (n - 1)
        return dict(
            link_scale=ones, own_scale=one,
            demand_skew=jnp.where(idx == hot, skew_hot, skew_rest),
            ego_compute=one, peer_compute=ones,
        )

    out = jax.lax.switch(
        jnp.asarray(code, jnp.int32),
        [_clean, _hot_owner, _slow_worker, _demand_skew], None,
    )
    return out


# ------------------------------------------------------------------- state
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EnvState:
    key: jax.Array
    scenario: ClusterScenario
    params: cm.CostModelParams
    step_pos: jax.Array
    prev_window: jax.Array
    prev_weights: jax.Array
    obs: jax.Array
    done: jax.Array
    total_energy: jax.Array
    total_time: jax.Array
    # fluid fabric state (queue_sim superset)
    util_state: jax.Array
    delta_level: jax.Array
    backlog: jax.Array          # (n_owners,) ego queued miss work
    rb_backlog: jax.Array       # (n_owners,) ego queued rebuild work
    shared_backlog: jax.Array   # () ego ingress queued work
    peer_backlog: jax.Array     # (n_owners,) peer work queued at the
                                # ego-visible owner NICs (served first)
    peer_left: jax.Array        # () steps until the peers' next rebuild
    peer_window: jax.Array      # () the peers' current scripted window


# ----------------------------------------------------------------- dynamics
def _window_dynamics(
    cfg: ClusterEnvConfig,
    params: cm.CostModelParams,
    sc: ClusterScenario,
    key: jax.Array,
    window: jax.Array,
    weights: jax.Array,
    step_pos: jax.Array,
    util_state: jax.Array,
    delta_level: jax.Array,
    backlog: jax.Array,
    rb_backlog: jax.Array,
    shared_backlog: jax.Array,
    peer_backlog: jax.Array,
    peer_left: jax.Array,
    peer_window: jax.Array,
    eff_window: jax.Array | None = None,
) -> dict:
    """Run ``window`` ego training steps through the shared fluid fabric.

    Structurally queue_sim's ``_window_dynamics`` (same RNG stream, same
    float-op order on the ego path) extended with the three cluster terms:
    peer arrivals at the shared NICs, the per-step barrier + ring
    collective, and per-rank heterogeneity multipliers. Every extension
    is an exact-zero/one contribution when ``n_peers == 0`` and the
    factors are clean, so the zero-peer configuration reproduces
    queue_sim bitwise.
    """
    if eff_window is None:
        eff_window = window
    n_owners = cfg.n_owners
    base = sc.base
    slope = params.gamma_c / params.beta
    t_base = jnp.asarray(params.t_base, jnp.float32) * sc.ego_compute
    slack = cfg.slack_steps * t_base

    # the SHARED fluid cost law (queue_sim is the single source of truth;
    # demand_skew multiplies per-owner demand, ones when clean)
    h_o, miss_rows, miss_work, active, rb_work, rb_cpu = qs.action_volumes(
        params, window, weights, n_owners, demand=sc.demand_skew
    )
    miss_work_ref, active_ref, rb_work_ref, rb_cpu_ref = (
        qs.reference_volumes(params, n_owners, demand=sc.demand_skew)
    )
    if cfg.mem_budget_frac > 0.0:
        # tiered-store pressure (queue_sim's spill law verbatim): the
        # over-budget working set re-fetches over the shared NICs, so
        # memory pressure compounds with the emergent congestion
        miss_work = miss_work * qs.mem_spill(cfg, window)
        rb_work = rb_work * qs.mem_spill(cfg, window)
        rb_cpu = jnp.sum(params.alpha_rpc + rb_work)
        miss_work_ref = miss_work_ref * qs.mem_spill(cfg, REF_W)
        rb_work_ref = rb_work_ref * qs.mem_spill(cfg, REF_W)
        rb_cpu_ref = jnp.sum(params.alpha_rpc + rb_work_ref)
    # the closure carries the ego's compute-scaled t_base/slack; phi below
    # carries the link_scale, queue_ carries the peer backlog — the same
    # law prices both envs
    step_cost = qs.make_step_cost(
        params, slope, t_base, slack, base.shared_factor
    )

    # ring-collective constants (jnp twin of ring_collective_cost): at
    # zero live peers phases == 0 so every sync quantity is exactly 0.0
    scatter = cfg.sync == "reduce_scatter"

    def collective(n_active):
        if cfg.sync == "none":
            z = jnp.asarray(0.0, jnp.float32)
            return z, z
        phases = (n_active - 1.0) * (1.0 if scatter else 2.0)
        chunk = cfg.grad_bytes / jnp.maximum(n_active, 1.0)
        per_phase = params.alpha_rpc + params.beta * chunk
        wall = phases * per_phase
        cpu = phases * (per_phase + params.beta * chunk)
        return wall, cpu

    peer_on = (
        jnp.arange(n_owners) < sc.n_peers
    ).astype(jnp.float32)                       # peer i == rank i+1
    n_live = jnp.sum(peer_on)

    def substep(carry, i):
        (key, util_state, delta_level, backlog, rb_backlog, shared_backlog,
         peer_backlog, peer_left, peer_window, acc) = carry
        live = (i < eff_window).astype(jnp.float32)
        step = step_pos + i
        key, k_markov, k_step = jax.random.split(key, 3)

        new_util_state = qs.dr.markov_onoff_update(
            k_markov, util_state, base.p_on, base.p_off
        )
        new_delta_level = qs.dr.step_trace_update(
            k_step, delta_level, base.p_switch, base.level_max
        )
        util_state_i = jnp.where(live > 0, new_util_state, util_state)
        delta_level_i = jnp.where(live > 0, new_delta_level, delta_level)

        u = qs._utilization(base, util_state_i, step, n_owners)
        d = qs._delta(cfg, base, delta_level_i, step)
        phi_base = (1.0 - u) / (1.0 + slope * d)
        phi = phi_base * sc.link_scale
        sigma_base = 1.0 / phi_base

        # AR penalty from the injected sigma only — the deployed worker
        # computes it from fabric.sigma(), which has no link-rate term
        ar = params.kappa_ar * jnp.maximum(jnp.max(sigma_base) - 1.0, 0.0)

        # ---- scripted peers: current window -> miss/rebuild volumes ----
        sigma_seen = jnp.max(1.0 / phi)
        boundary = (peer_left <= 0.0).astype(jnp.float32)
        w_target = jnp.where(
            sc.peer_reactive > 0.0,
            jnp.clip(
                qs.REFERENCE_WINDOW / jnp.sqrt(jnp.maximum(sigma_seen, 1.0)),
                4.0, 32.0,
            ),
            REF_W,
        )
        w_peer = jnp.where(boundary > 0, w_target, peer_window)
        h_peer = cm.hit_rate(params, w_peer)
        peer_miss_rows = params.remote_nodes * (1.0 - h_peer) / n_owners
        peer_mw = params.beta * peer_miss_rows * params.feature_bytes
        peer_act = jnp.clip(peer_miss_rows * ACTIVE_ROWS_SCALE, 0.0, 1.0)
        peer_rb = (
            REBUILD_FETCH_FRAC * (params.remote_nodes / n_owners)
            * w_peer ** params.rebuild_c * h_peer
            * params.beta * params.feature_bytes
        )
        # arrivals at ego slot i: every live peer r != i sends its
        # per-owner share there (peer i owns that NIC and skips it) —
        # the rebuild bulk lands synchronized at the peers' boundary
        others = jnp.maximum(n_live - peer_on, 0.0)
        arrive = sc.demand_skew * others * (
            peer_act * peer_mw + boundary * peer_rb
        )

        # ---- ego cost: misses queue behind peer work AND own backlogs --
        t_step, stall, rb_leak, e_step, wall_o = step_cost(
            d, phi, ar, active, miss_work,
            backlog + rb_backlog + peer_backlog,
            rb_backlog + backlog + peer_backlog,
            jnp.sign(jnp.sum(rb_backlog)), shared_backlog, rb_cpu, window,
        )
        t_ref, _, _, e_ref, _ = step_cost(
            d, phi, ar, active_ref, miss_work_ref,
            jnp.zeros((n_owners,)), rb_work_ref,
            jnp.asarray(1.0), jnp.asarray(0.0), rb_cpu_ref, REF_W,
        )

        # ---- barrier + ring collective (the per-step gradient sync) ----
        # peer wall: its miss fetch behind the same shared queues, plus
        # its fetch from the ego's own partition NIC (untracked queue,
        # rate own_scale) — a hot NIC at the ego's partition slows peers
        # without ever appearing in the ego's per-owner slots
        q_tot = backlog + rb_backlog + peer_backlog
        peer_wall = jnp.max(
            peer_act * (params.alpha_rpc + PROP_RTT_S_PER_MS * d)
            + (q_tot + peer_act * peer_mw) / phi
        )
        own_phi = jnp.maximum(jnp.mean(phi_base) * sc.own_scale, 1e-6)
        wall_own = peer_act * (
            params.alpha_rpc + PROP_RTT_S_PER_MS * jnp.mean(d)
        ) + peer_act * peer_mw / own_phi
        peer_raw = jnp.maximum(peer_wall, wall_own)
        peer_slack = cfg.slack_steps * params.t_base * sc.peer_compute
        peer_stall = jnp.maximum(peer_raw - peer_slack, 0.0)
        t_peer = params.t_base * sc.peer_compute + peer_stall
        peer_max = jnp.max(peer_on * t_peer)

        coll_wall, coll_cpu = collective(1.0 + n_live)
        wait = jnp.maximum(peer_max - t_step, 0.0)
        sync_s = wait + coll_wall
        # EnergyMeter.record_sync: GPU idles through the wait, CPU pays
        # base power for it plus RPC protocol work for the collective
        e_sync = (
            (params.p_gpu_idle + params.p_cpu_base) * sync_s
            + params.p_cpu_rpc * coll_cpu
        )
        wait_ref = jnp.maximum(peer_max - t_ref, 0.0)
        e_sync_ref = (
            (params.p_gpu_idle + params.p_cpu_base) * (wait_ref + coll_wall)
            + params.p_cpu_rpc * coll_cpu
        )
        t_wall = t_step + sync_s

        # ---- drain: peer work first (in-queue ahead), then ego rebuild,
        #      then ego misses; the sync wait is drain time too
        cap = phi * t_wall
        peer_served = jnp.minimum(peer_backlog, cap)
        cap_ego = cap - peer_served
        rb_served = jnp.minimum(rb_backlog, cap_ego)
        new_rb = rb_backlog - rb_served
        new_backlog = jnp.maximum(
            backlog + active * miss_work - (cap_ego - rb_served), 0.0
        )
        new_peer = peer_backlog - peer_served + arrive
        new_shared = jnp.where(
            base.shared_factor > 0.0,
            jnp.maximum(
                shared_backlog + jnp.sum(active * miss_work)
                - jnp.maximum(base.shared_factor, 1e-6) * t_wall,
                0.0,
            ),
            0.0,
        )
        backlog = jnp.where(live > 0, new_backlog, backlog)
        rb_backlog = jnp.where(live > 0, new_rb, rb_backlog)
        shared_backlog = jnp.where(live > 0, new_shared, shared_backlog)
        peer_backlog = jnp.where(live > 0, new_peer, peer_backlog)
        peer_left_new = jnp.where(boundary > 0, w_peer - 1.0, peer_left - 1.0)
        peer_left = jnp.where(live > 0, peer_left_new, peer_left)
        peer_window = jnp.where(live > 0, w_peer, peer_window)

        per_row = wall_o / jnp.maximum(miss_rows, 1e-6)
        rb_wait = jnp.minimum(jnp.max(rb_backlog / phi), stall)

        acc = {
            "t": acc["t"] + live * t_wall,
            "e": acc["e"] + live * (e_step + e_sync),
            "e_ref": acc["e_ref"] + live * (e_ref + e_sync_ref),
            "stall": acc["stall"] + live * (stall + sync_s),
            "rb_wait": acc["rb_wait"] + live * (rb_wait + rb_leak),
            "per_row": acc["per_row"] + live * active * per_row,
            "active": acc["active"] + live * active,
            "n": acc["n"] + live,
        }
        return (
            key, util_state_i, delta_level_i, backlog, rb_backlog,
            shared_backlog, peer_backlog, peer_left, peer_window, acc,
        ), None

    acc0 = {
        "t": jnp.asarray(0.0), "e": jnp.asarray(0.0),
        "e_ref": jnp.asarray(0.0), "stall": jnp.asarray(0.0),
        "rb_wait": jnp.asarray(0.0),
        "per_row": jnp.zeros((n_owners,)),
        "active": jnp.zeros((n_owners,)),
        "n": jnp.asarray(0.0),
    }
    carry = (
        key, util_state, delta_level, backlog, rb_backlog + rb_work,
        shared_backlog, peer_backlog, peer_left, peer_window, acc0,
    )
    carry, _ = jax.lax.scan(substep, carry, jnp.arange(MAX_WINDOW))
    (key, util_state, delta_level, backlog, rb_backlog, shared_backlog,
     peer_backlog, peer_left, peer_window, acc) = carry

    out = qs.summarize_window(params, acc, n_owners)
    out.update({
        "h_o": h_o,
        "key": key,
        "util_state": util_state,
        "delta_level": delta_level,
        "backlog": backlog,
        "rb_backlog": rb_backlog,
        "shared_backlog": shared_backlog,
        "peer_backlog": peer_backlog,
        "peer_left": peer_left,
        "peer_window": peer_window,
    })
    return out


def reset(
    cfg: ClusterEnvConfig, key: jax.Array, params: cm.CostModelParams
) -> EnvState:
    k_pool, k_sc, k_dyn, k_obs, k_next = jax.random.split(key, 5)
    scenario = sample_scenario(k_pool, k_sc, cfg)

    n = cfg.n_owners
    weights = jnp.full((n,), 1.0 / n)
    window = jnp.asarray(qs.REFERENCE_WINDOW, jnp.float32)
    zeros = jnp.zeros((n,))
    z = jnp.asarray(0.0, jnp.float32)
    dyn = _window_dynamics(
        cfg, params, scenario, k_dyn, window, weights,
        z, zeros, zeros, zeros, zeros, z,
        zeros, z, REF_W,
    )
    obs = qs._observe(cfg, params, k_obs, dyn, window, weights, z)
    return EnvState(
        key=k_next, scenario=scenario, params=params,
        step_pos=jnp.asarray(0.0, jnp.float32),
        prev_window=window, prev_weights=weights, obs=obs,
        done=jnp.asarray(False),
        total_energy=jnp.asarray(0.0, jnp.float32),
        total_time=jnp.asarray(0.0, jnp.float32),
        util_state=zeros, delta_level=zeros,
        backlog=zeros, rb_backlog=zeros,
        shared_backlog=z,
        peer_backlog=zeros, peer_left=z, peer_window=REF_W,
    )


def sample_scenario(
    k_pool: jax.Array, k_sc: jax.Array, cfg: ClusterEnvConfig
) -> ClusterScenario:
    """One episode's full recipe, given the two sub-keys reset carved out.

    The overlay stream uses (k_pool, k_sc) EXACTLY as queue_sim.reset
    does; the cluster factors draw only from keys folded off k_pool —
    which is what makes the zero-peer/clean configuration reduce to
    queue_sim bit-for-bit."""
    pool = jnp.asarray(cfg.scenario_pool, jnp.int32)
    code = pool[jax.random.randint(k_pool, (), 0, pool.shape[0])]
    base = qs.sample_scenario(k_sc, code, cfg.total_steps, cfg.n_owners)

    kc = jax.random.fold_in(k_pool, 0xC1)
    k_kind, k_peers, k_factors, k_react = jax.random.split(kc, 4)
    cpool = jnp.asarray(cfg.cluster_pool, jnp.int32)
    ckind = cpool[jax.random.randint(k_kind, (), 0, cpool.shape[0])]
    ppool = jnp.asarray(cfg.resolved_peer_pool(), jnp.int32)
    n_peers = ppool[jax.random.randint(k_peers, (), 0, ppool.shape[0])]
    factors = sample_cluster_factors(k_factors, ckind, cfg)
    if cfg.peer_policy == "static":
        reactive = jnp.asarray(0.0, jnp.float32)
    elif cfg.peer_policy == "greendygnn":
        reactive = jnp.asarray(1.0, jnp.float32)
    else:
        reactive = (
            jax.random.uniform(k_react, ()) < 0.5
        ).astype(jnp.float32)
    return ClusterScenario(
        base=base, cluster_kind=ckind,
        n_peers=jnp.asarray(n_peers, jnp.int32),
        peer_reactive=reactive, **factors,
    )


def step(
    cfg: ClusterEnvConfig, state: EnvState, action: jax.Array
) -> tuple[EnvState, jax.Array, jax.Array, jax.Array]:
    """One MDP decision: decode action, run W ego steps through the
    shared fabric (peers riding along), emit (s', r, done)."""
    from repro.core import controller as ctl

    window, weights = ctl.decode_action(action, cfg.n_owners)
    key, k_dyn, k_obs = jax.random.split(state.key, 3)

    w_eff = jnp.minimum(window, cfg.total_steps - state.step_pos)
    dyn = _window_dynamics(
        cfg, state.params, state.scenario, k_dyn, window, weights,
        state.step_pos, state.util_state, state.delta_level,
        state.backlog, state.rb_backlog, state.shared_backlog,
        state.peer_backlog, state.peer_left, state.peer_window,
        eff_window=w_eff,
    )
    obs = qs._observe(
        cfg, state.params, k_obs, dyn, window, weights,
        state.step_pos + w_eff,
    )
    from repro.core.controller import LAMBDA_THRASH

    thrash = jnp.sum(jnp.abs(weights - state.prev_weights))
    reward = -dyn["e_step"] / dyn["e_ref"] - LAMBDA_THRASH * thrash

    new_pos = state.step_pos + w_eff
    done = new_pos >= cfg.total_steps
    new_state = EnvState(
        key=key, scenario=state.scenario, params=state.params,
        step_pos=new_pos, prev_window=window, prev_weights=weights,
        obs=obs, done=done,
        total_energy=state.total_energy + dyn["e_step"] * w_eff,
        total_time=state.total_time + dyn["t_step"] * w_eff,
        util_state=dyn["util_state"], delta_level=dyn["delta_level"],
        backlog=dyn["backlog"], rb_backlog=dyn["rb_backlog"],
        shared_backlog=dyn["shared_backlog"],
        peer_backlog=dyn["peer_backlog"], peer_left=dyn["peer_left"],
        peer_window=dyn["peer_window"],
    )
    return new_state, obs, reward, done


def rollout_policy(
    cfg: ClusterEnvConfig,
    key: jax.Array,
    params: cm.CostModelParams,
    policy_fn,
    max_decisions: int = 1024,
) -> dict:
    """Roll one episode with ``policy_fn(obs, key) -> action`` (same
    contract as the sibling envs)."""
    state = reset(cfg, key, params)

    def body(carry, _):
        state, k = carry
        k, k_act = jax.random.split(k)
        action = policy_fn(state.obs, k_act)
        nxt, _, reward, done = step(cfg, state, action)
        frozen = jax.tree.map(
            lambda a, b: jnp.where(state.done, a, b), state, nxt
        )
        out = {
            "window": nxt.prev_window,
            "reward": reward,
            "step_pos": state.step_pos,
            "active": ~state.done,
        }
        return (frozen, k), out

    (final, _), trace = jax.lax.scan(
        body, (state, key), None, length=max_decisions
    )
    return {
        "total_energy": final.total_energy,
        "total_time": final.total_time,
        "trace": trace,
    }
