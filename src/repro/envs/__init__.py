"""repro.envs — the unified RL training-environment registry.

Every training environment implements one protocol, so ``dqn.train_dqn``,
``train/policy.py``, ``scripts/export_qnet.py`` and the benchmark
gauntlets enumerate them uniformly:

    reset(cfg, key, params) -> EnvState        # EnvState.obs, .done, ...
    step(cfg, state, action) -> (EnvState, obs, reward, done)

Lineage (each env captures strictly more of the eval system; see
DESIGN.md "Training on emergent congestion — the cluster twin"):

  ============ ============================= ===========================
  name          module                        congestion model
  ============ ============================= ===========================
  analytic      ``core.simulator``            parametric Eq. 1-4 law,
                                              legacy archetype schedule
  table         ``core.table_sim``            trace-calibrated hit/stall
                                              tables, parametric sigma
  queue         ``core.queue_sim``            single-requester fluid
                                              fabric twin, injected
                                              scenario-conditioned load
  cluster       ``envs.cluster_sim``          P-requester fluid twin:
                                              shared owner NICs, peer
                                              rebuild storms, barrier +
                                              ring-collective coupling,
                                              rank heterogeneity,
                                              demand skew
  ============ ============================= ===========================

``core.queue_sim`` predates this package and stays where it is; it is
re-exported here (``repro.envs.queue_sim``) so new code can import every
env from one place while old imports keep working.
"""
from __future__ import annotations

from repro.core import queue_sim  # noqa: F401  (re-export, compatibility)
from repro.envs import cluster_sim  # noqa: F401

# Named training environments, in lineage order.
ENVS = ("analytic", "table", "queue", "cluster")


def resolve_env(env, params_pool=None):
    """Resolve an env spec (name, module, or None) to an env module.

    ``None`` keeps the legacy behavior of inferring analytic-vs-table
    from the pool's parameter type (the pre-registry contract).
    """
    from repro.core import simulator as sim
    from repro.core import table_sim

    if env is None:
        return (
            table_sim
            if isinstance(params_pool, table_sim.TableParams) else sim
        )
    if isinstance(env, str):
        try:
            return {
                "analytic": sim,
                "table": table_sim,
                "queue": queue_sim,
                "cluster": cluster_sim,
            }[env]
        except KeyError:
            raise ValueError(
                f"unknown training env {env!r}; expected one of {ENVS}"
            ) from None
    return env


__all__ = ["ENVS", "cluster_sim", "queue_sim", "resolve_env"]
