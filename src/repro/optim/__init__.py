from repro.optim.optimizers import (  # noqa: F401
    OptState,
    adam,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    sgd,
    warmup_cosine_schedule,
)
