"""Minimal optax-style optimizer library (no external deps).

An optimizer is a pair (init_fn, update_fn):
    state = init_fn(params)
    updates, state = update_fn(grads, state, params)
    params = apply_updates(params, updates)

All transforms are pure pytree functions, jit/shard-friendly: the optimizer
state is sharded exactly like the parameters by construction (same tree
structure and per-leaf shapes), which is what ZeRO-style sharding needs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jax.Array
    mu: PyTree
    nu: PyTree


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: x * scale, tree), norm


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def _schedule_value(lr: float | Callable[[jax.Array], jax.Array], step: jax.Array):
    return lr(step) if callable(lr) else lr


def adamw(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: float | None = None,
) -> Optimizer:
    """AdamW with optional global-norm clipping and decoupled weight decay."""

    def init(params: PyTree) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))

    def update(grads: PyTree, state: OptState, params: PyTree):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr = _schedule_value(learning_rate, step)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        updates = jax.tree.map(
            lambda m, v, p: -lr
            * ((m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(jnp.float32)),
            mu,
            nu,
            params,
        )
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


def adam(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    max_grad_norm: float | None = None,
) -> Optimizer:
    return adamw(learning_rate, b1, b2, eps, 0.0, max_grad_norm)


def sgd(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    momentum: float = 0.0,
) -> Optimizer:
    def init(params: PyTree) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)

    def update(grads: PyTree, state: OptState, params: PyTree):
        step = state.step + 1
        lr = _schedule_value(learning_rate, step)
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state.mu, grads
        )
        updates = jax.tree.map(lambda m: -lr * m, mu)
        return updates, OptState(step=step, mu=mu, nu=state.nu)

    return Optimizer(init, update)


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step: jax.Array) -> jax.Array:
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)

    return fn


def warmup_cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1), final_frac)

    def fn(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return fn
