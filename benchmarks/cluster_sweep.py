"""Emergent-vs-injected congestion across cluster sizes P in {2, 4, 8}.

For each P, every partition runs a live trainer over ONE shared
requester-aware fabric (``repro.train.cluster``), and methods
dgl / bgl / static (static_w) / greendygnn are compared on *cluster-total*
energy under two families of scenarios:

  emergent (NO background overlay — congestion comes only from the P
  trainers' real traffic):
    clean         symmetric cluster; contention = P-way NIC sharing
    hot_owner     partition 0's NIC at a fraction of the base rate — a
                  hot/slow feature owner; every worker's misses to it
                  incast-collapse at that NIC
    slow_worker   rank 0 computes slower (t_base x) — a straggler whose
                  barrier drag and lagging rebuilds feed back into peers
    demand_skew   partition 0 owns a disproportionate share of the
                  globally-hot nodes (``partition_graph(degree_bias=)``)
                  — every worker directs outsized miss demand at one NIC,
                  stressing per-owner cache allocation, not window size

  injected (the PR-2 background overlays, now *on top of* the emergent
  traffic): bursty_markov, incast

The greendygnn policy deployed on every rank is trained IN the cluster
twin (``repro.envs.cluster_sim`` via
``policy.get_or_train_policy(env="cluster", n_workers=P)``) — per-P
checkpoints, new default. ``greendygnn_queue`` deploys the same
architecture trained in the single-requester queue env
(``core/queue_sim``), the PR-3 state of the art, as the ablation the
acceptance gate compares against.

    PYTHONPATH=src python benchmarks/cluster_sweep.py --steps 96
    PYTHONPATH=src python benchmarks/cluster_sweep.py --workers 4 --check
    PYTHONPATH=src python benchmarks/cluster_sweep.py --workers 4 --mixture

``--check`` asserts the PR-5 acceptance at P=4: emergent queueing on
every no-overlay scenario, the cluster-trained greendygnn beats the BEST
static policy (min over dgl/bgl/static_w) on >= 2 emergent scenarios,
is <= the queue-trained greendygnn on every emergent scenario (one-sided
5% band on clean), and strictly better on >= 2 of
{hot_owner, slow_worker, demand_skew}.

``--mixture`` adds the policy-heterogeneity axis (per-rank
``ClusterConfig.methods``): mixed fleets — greendygnn only on the
straggler rank vs only on the symmetric ranks — under slow_worker
physics, against the homogeneous fleets.

``--mem-budget`` (PR 7) adds the tiered-memory axis: each level is a
host-tier byte budget (fraction of the graph's total feature bytes, or
the named presets tight=0.2 / loose=0.6) deployed through
``RunConfig.mem_budget`` -> ``repro.store.TieredFeatureStore``, so
memory pressure converts into block traffic on the SAME shared fabric
the policies reason about. The greendygnn cell deploys a headroom-aware
policy trained in the cluster twin under matching pressure
(``ClusterEnvConfig(mem_budget_frac=..., observe_headroom=True)``,
per-level checkpoints ``qnet_sweep_mem_<level>_cluster_p<P>``). Rows
carry per-tier hit/eviction attribution (``ClusterReport.tier_counts``),
and with ``--check`` the greendygnn cells are paired-run
digest-compared and an explicit unlimited ``MemoryBudget`` is asserted
bit-identical to the pre-PR store.

    PYTHONPATH=src python benchmarks/cluster_sweep.py \\
        --workers 4 --mem-budget tight,loose --check
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import numpy as np

try:  # repo root (python -m benchmarks.cluster_sweep / python benchmarks/..)
    from benchmarks.common import RESULTS_DIR, base_cfg, save_json
except ImportError:  # cwd = benchmarks/
    from common import RESULTS_DIR, base_cfg, save_json

from repro.graph.partition import hot_share, partition_graph
from repro.train import gnn_trainer as gt
from repro.train import policy as pol
from repro.train.cluster import (
    ClusterConfig,
    build_cluster_traces,
    run_cluster,
)

STATIC_METHODS = ("dgl", "bgl", "static_w")
ADAPTIVE_METHODS = ("greendygnn", "greendygnn_queue")
METHOD_LABEL = {
    "static_w": "static",
    "greendygnn": "gdg-cluster",
    "greendygnn_queue": "gdg-queue",
}
INJECTED = ("bursty_markov", "incast")
# the non-clean emergent scenarios the strict-win criterion ranges over
EMERGENT_STRESS = ("hot_owner", "slow_worker", "demand_skew")
# named --mem-budget presets: host-tier budget as a fraction of the
# graph's total feature bytes
MEM_LEVELS = {"tight": 0.2, "loose": 0.6}


def emergent_scenarios(n_parts: int, hot_rate: float, slow_factor: float):
    """Name -> (fabric scenario, ClusterConfig physics kwargs, skewed?).

    ``demand_skew`` carries no fabric/physics knobs — its congestion
    comes entirely from the degree-biased partition (third element)."""
    hot = np.ones(n_parts)
    hot[0] = hot_rate
    slow = np.ones(n_parts)
    slow[0] = slow_factor
    return {
        "clean": ("clean", {}, False),
        "hot_owner": ("clean", {"link_rate_scale": tuple(hot)}, False),
        "slow_worker": ("clean", {"compute_scale": tuple(slow)}, False),
        "demand_skew": ("clean", {}, True),
    }


def calib_pool(cfg0, bundle):
    """Algorithm-1 calibration for this P's trace, as an episode pool."""
    theta, _ = pol.calibrate_from_bundle(bundle, cfg0)
    return pol.make_params_pool([theta])


def get_q_fns(cfg0, pool, iterations: int, force: bool,
              wanted) -> dict:
    """Per-P Double-DQN policies: cluster-twin-trained (the deployed
    default) and queue-env-trained (the train/eval-gap ablation) — each
    trained only when a requested method actually deploys it.

    The controller's obs/action spaces are sized by n_owners = P - 1, so
    each P gets its own Algorithm-1 calibration + checkpoints
    (``qnet_sweep_cluster_p<P>`` / ``qnet_sweep_p<P>_queue``).
    """
    wanted = [m for m in ADAPTIVE_METHODS if m in wanted]
    if not wanted:
        return {}
    P = cfg0.n_parts
    q_fns = {}
    if "greendygnn" in wanted:
        q_fns["greendygnn"], _ = pol.get_or_train_policy(
            pool, name="qnet_sweep", iterations=iterations, force=force,
            env="cluster", n_workers=P,
        )
    if "greendygnn_queue" in wanted:
        q_fns["greendygnn_queue"], _ = pol.get_or_train_policy(
            pool, name=f"qnet_sweep_p{P}", iterations=iterations,
            force=force, env="queue", n_owners=P - 1,
        )
    return q_fns


def _run_cell(cfg0, method, fabric_sc, physics, bundles, q_fns, P, sync,
              trace=False):
    trainer_method = (
        "greendygnn" if method in ADAPTIVE_METHODS else method
    )
    cfg_m = dataclasses.replace(
        cfg0, method=trainer_method, scenario=fabric_sc,
        q_fn=q_fns.get(method), trace=trace,
    )
    rep = run_cluster(
        cfg_m,
        ClusterConfig(n_workers=P, sync=sync, **physics),
        trace_bundles=bundles,
    )
    t = rep.totals_kj()
    return rep, {
        "total_kj": t["total_kj"],
        "gpu_kj": t["gpu_kj"],
        "cpu_kj": t["cpu_kj"],
        "wall_s": t["wall_s"],
        "queue_s": rep.total_queue_s,
        "hit_rate": float(np.mean([
            float(r.hit_rate_per_epoch.mean())
            for r in rep.results
        ])),
        "per_worker": rep.per_worker(),
    }


def run_sweep(args) -> dict:
    steps_per_epoch = args.steps_per_epoch
    n_epochs = max(args.steps // steps_per_epoch, 2)
    methods = args.methods.split(",")
    worker_counts = [int(p) for p in args.workers.split(",")]

    out: dict = {"rows": {}, "dataset": args.dataset, "batch": args.batch,
                 "n_epochs": n_epochs, "steps_per_epoch": steps_per_epoch,
                 "seed": args.seed, "sync": args.sync,
                 "demand_bias": args.demand_bias}
    for P in worker_counts:
        cfg0 = dataclasses.replace(
            base_cfg(args.dataset, args.batch),
            n_parts=P, n_epochs=n_epochs, steps_per_epoch=steps_per_epoch,
            seed=args.seed,
        )
        print(f"\n=== P={P}: building {P} per-partition traces...",
              flush=True)
        bundles = build_cluster_traces(cfg0, P)
        # demand_skew partitions the SAME graph with partition-0 degree
        # bias, so its congestion is pure demand concentration
        graph = bundles[0][0]
        owner_skew = partition_graph(
            graph, P, seed=0, degree_bias=args.demand_bias, biased_part=0,
        )
        skew_bundles = build_cluster_traces(
            cfg0, P, graph=graph, owner=owner_skew
        )
        out.setdefault("hot_share", {})[P] = {
            "balanced": hot_share(graph, bundles[0][1], P).tolist(),
            "demand_skew": hot_share(graph, owner_skew, P).tolist(),
        }
        wanted = set(methods)
        if args.mixture:
            wanted.add("greendygnn")  # the mixture axis deploys it
        need_pool = bool(
            wanted & set(ADAPTIVE_METHODS)
        ) or bool(args.mem_budget)
        pool = calib_pool(cfg0, bundles[0]) if need_pool else None
        q_fns = get_q_fns(
            cfg0, pool, args.iterations, args.force, wanted
        )

        scenarios = dict(
            emergent_scenarios(P, args.hot_rate, args.slow_factor)
        )
        for sc in INJECTED:
            scenarios[f"injected:{sc}"] = (sc, {}, False)

        out["rows"][P] = {}
        header = f"{'scenario':>22} " + "".join(
            f"{METHOD_LABEL.get(m, m):>12}" for m in methods
        )
        print(f"cluster-total energy [kJ], P={P} workers, "
              f"sync={args.sync}\n{header}")
        for name, (fabric_sc, physics, skewed) in scenarios.items():
            out["rows"][P][name] = {}
            cells = []
            for m in methods:
                rep, row = _run_cell(
                    cfg0, m, fabric_sc, physics,
                    skew_bundles if skewed else bundles, q_fns, P,
                    args.sync, trace=args.trace,
                )
                if args.trace and rep.trace is not None:
                    from repro.obs import reconcile, write_trace

                    reconcile(rep.trace)  # hard-fail on a broken ledger
                    rep.trace["meta"]["scenario"] = name
                    tp = write_trace(
                        os.path.join(
                            RESULTS_DIR, "traces",
                            f"cluster_sweep_p{P}_{name}_{m}.json",
                        ),
                        rep.trace,
                    )
                    print(f"    trace -> {tp}")
                out["rows"][P][name][m] = row
                cells.append(f"{row['total_kj']:12.3f}")
            q = out["rows"][P][name][methods[0]]["queue_s"]
            print(f"{name:>22} " + "".join(cells) + f"   (queue {q:.3f}s)")

        if args.mixture:
            out.setdefault("mixtures", {})[P] = run_mixture(
                cfg0, bundles, q_fns, P, args
            )

        if args.mem_budget:
            out.setdefault("mem", {})[P] = run_mem_axis(
                cfg0, bundles, pool, P, args
            )
    return out


def parse_mem_levels(spec: str) -> dict:
    """'tight,loose' / '0.15,0.5' -> {level name: budget fraction}."""
    levels = {}
    for tok in spec.split(","):
        tok = tok.strip()
        if not tok:
            continue
        levels[tok] = MEM_LEVELS.get(tok, None)
        if levels[tok] is None:
            levels[tok] = float(tok)
    if not levels:
        raise ValueError(f"no budget levels in --mem-budget={spec!r}")
    return levels


def _feature_bytes(graph) -> float:
    """Total feature bytes the budget fractions are relative to."""
    if graph.features is not None:
        return float(graph.features.nbytes)
    return float(graph.n_nodes * graph.feature_source.bytes_per_row)


def _run_mem_cell(cfg0, method, budget, bundles, q_fn, P, sync):
    cfg_m = dataclasses.replace(
        cfg0, method="greendygnn" if method == "greendygnn" else method,
        scenario="clean", q_fn=q_fn if method == "greendygnn" else None,
        mem_budget=budget,
    )
    rep = run_cluster(
        cfg_m, ClusterConfig(n_workers=P, sync=sync), trace_bundles=bundles,
    )
    t = rep.totals_kj()
    return rep, {
        "total_kj": t["total_kj"],
        "gpu_kj": t["gpu_kj"],
        "cpu_kj": t["cpu_kj"],
        "wall_s": t["wall_s"],
        "queue_s": rep.total_queue_s,
        "tier_counts": rep.tier_counts(),
        "per_worker": rep.per_worker(),
    }


def run_mem_axis(cfg0, bundles, pool, P, args) -> dict:
    """--mem-budget axis: static fleets vs the headroom-aware greendygnn
    under tiered host budgets on the clean emergent fabric.

    Per level, the greendygnn cell deploys a policy trained in the
    cluster twin under MATCHING memory pressure
    (``mem_budget_frac=frac, observe_headroom=True`` — 24-dim obs); the
    deployed worker observes the real store's headroom, so train and
    eval see the same state surface. With ``--check`` the greendygnn
    cell is run twice and digest- and tier-count-compared (the sweep's
    determinism evidence), and an explicit *unlimited* ``MemoryBudget``
    is asserted report-digest-identical to the legacy in-RAM store.
    """
    from repro.analysis.digest import report_digest
    from repro.store import MemoryBudget

    graph = bundles[0][0]
    feat_bytes = _feature_bytes(graph)
    levels = parse_mem_levels(args.mem_budget)
    methods = list(STATIC_METHODS) + ["greendygnn"]
    out = {"feature_bytes": feat_bytes, "chunk_rows": args.chunk_rows,
           "levels": levels, "rows": {}}

    cfg_st = dataclasses.replace(
        cfg0, method="static_w", scenario="clean", q_fn=None,
    )
    legacy = run_cluster(
        cfg_st, ClusterConfig(n_workers=P, sync=args.sync),
        trace_bundles=bundles,
    )
    unlim = run_cluster(
        dataclasses.replace(
            cfg_st, mem_budget=MemoryBudget(device_payloads=False)
        ),
        ClusterConfig(n_workers=P, sync=args.sync), trace_bundles=bundles,
    )
    out["unlimited_parity"] = (
        report_digest(legacy) == report_digest(unlim)
    )

    print(f"\n--mem-budget axis @ P={P} "
          f"(total feature bytes {feat_bytes / 1e6:.2f} MB, "
          f"chunk {args.chunk_rows} rows, unlimited parity: "
          f"{out['unlimited_parity']})")
    header = f"{'budget':>22} " + "".join(
        f"{METHOD_LABEL.get(m, m):>12}" for m in methods
    )
    print(header)
    for name, frac in levels.items():
        budget = MemoryBudget(
            host_bytes=frac * feat_bytes, chunk_rows=args.chunk_rows,
        )
        q_fn, _ = pol.get_or_train_policy(
            pool, name=f"qnet_sweep_mem_{name}", iterations=args.iterations,
            force=args.force, env="cluster", n_workers=P,
            cluster_kwargs={
                "mem_budget_frac": float(frac), "observe_headroom": True,
            },
        )
        out["rows"][name] = {}
        cells = []
        for m in methods:
            rep, row = _run_mem_cell(
                cfg0, m, budget, bundles, q_fn, P, args.sync
            )
            if args.check and m == "greendygnn":
                rep2, row2 = _run_mem_cell(
                    cfg0, m, budget, bundles, q_fn, P, args.sync
                )
                row["deterministic"] = (
                    report_digest(rep) == report_digest(rep2)
                    and row["tier_counts"] == row2["tier_counts"]
                )
            out["rows"][name][m] = row
            cells.append(f"{row['total_kj']:12.3f}")
        tc = out["rows"][name]["greendygnn"]["tier_counts"]
        print(f"{name:>22} " + "".join(cells)
              + f"   (evict {tc['evictions']}, host hit {tc['host_hits']},"
                f" device hit {tc['device_hits']})")
    return out


def run_mixture(cfg0, bundles, q_fns, P, args) -> dict:
    """Policy-heterogeneity axis: mixed fleets under slow_worker physics.

    Per-rank ``ClusterConfig.methods``: the adaptive policy deployed only
    on the straggler rank (0) vs only on the symmetric ranks, against the
    homogeneous static and homogeneous adaptive fleets.
    """
    slow = np.ones(P)
    slow[0] = args.slow_factor
    physics = {"compute_scale": tuple(slow)}
    q = q_fns["greendygnn"]
    fleets = {
        "all_static": dict(methods=("static_w",) * P),
        "all_greendygnn": dict(methods=("greendygnn",) * P),
        "gdg_on_straggler": dict(
            methods=("greendygnn",) + ("static_w",) * (P - 1)
        ),
        "gdg_on_symmetric": dict(
            methods=("static_w",) + ("greendygnn",) * (P - 1)
        ),
    }
    rows = {}
    print(f"\npolicy mixtures under slow_worker physics, P={P}")
    for name, fleet in fleets.items():
        cfg_m = dataclasses.replace(
            cfg0, method="static_w", scenario="clean", q_fn=q,
        )
        rep = run_cluster(
            cfg_m,
            ClusterConfig(n_workers=P, sync=args.sync, **physics, **fleet),
            trace_bundles=bundles,
        )
        t = rep.totals_kj()
        rows[name] = {
            "total_kj": t["total_kj"],
            "wall_s": t["wall_s"],
            "queue_s": rep.total_queue_s,
            "methods": list(rep.methods),
            "per_worker": rep.per_worker(),
        }
        print(f"{name:>22} {t['total_kj']:12.3f} kJ  "
              f"(wall {t['wall_s']:.2f}s)")
    return rows


def check_acceptance(result: dict, check_p: int) -> None:
    """PR-5 acceptance at P=check_p (see module docstring)."""
    rows = result["rows"].get(check_p)
    assert rows is not None, f"--check needs P={check_p} in --workers"
    emergent = [n for n in rows if not n.startswith("injected:")]
    for m in ("greendygnn", "greendygnn_queue"):
        assert all(m in rows[n] for n in emergent), (
            f"--check needs method {m} in --methods"
        )

    # (0) PR-4 invariant: congestion is emergent on the no-overlay fabric
    for name in emergent:
        q = rows[name]["greendygnn"]["queue_s"]
        assert q > 0, f"no emergent queueing under {name} (queue_s={q})"

    # (1) PR-4 invariant: beats the best static fleet on >= 2 emergent
    static_wins = []
    for name in emergent:
        e_ad = rows[name]["greendygnn"]["total_kj"]
        statics = [
            rows[name][m]["total_kj"] for m in STATIC_METHODS
            if m in rows[name]
        ]
        assert statics, "--check needs at least one static method"
        if e_ad < min(statics):
            static_wins.append((name, e_ad, min(statics)))
    print(f"\n--check @ P={check_p}: cluster-trained greendygnn beats "
          f"best-static on {len(static_wins)}/{len(emergent)} emergent "
          "scenarios: "
          + ", ".join(f"{n} ({a:.3f} < {s:.3f} kJ)"
                      for n, a, s in static_wins))
    assert len(static_wins) >= 2, (
        "cluster-trained greendygnn must beat the best static policy on "
        f">= 2 emergent scenarios at P={check_p}, won {len(static_wins)}"
    )

    # (2) PR-5: the cluster twin closes the train/eval gap — <= the
    # queue-trained policy everywhere emergent (one-sided 5% band on
    # clean), strictly better on >= 2 stress scenarios
    strict = []
    for name in emergent:
        e_c = rows[name]["greendygnn"]["total_kj"]
        e_q = rows[name]["greendygnn_queue"]["total_kj"]
        tol = 1.05 if name == "clean" else 1.0 + 1e-9
        assert e_c <= e_q * tol, (
            f"cluster-trained ({e_c:.3f} kJ) worse than queue-trained "
            f"({e_q:.3f} kJ) under {name} at P={check_p}"
        )
        if name in EMERGENT_STRESS and e_c < e_q:
            strict.append((name, e_c, e_q))
    print(f"--check @ P={check_p}: cluster-trained <= queue-trained on "
          f"all emergent; strictly better on {len(strict)}/"
          f"{len(EMERGENT_STRESS)} stress scenarios: "
          + ", ".join(f"{n} ({a:.3f} < {b:.3f} kJ)" for n, a, b in strict))
    assert len(strict) >= 2, (
        "cluster-trained greendygnn must strictly beat queue-trained on "
        f">= 2 of {EMERGENT_STRESS} at P={check_p}, won {len(strict)}"
    )


def check_mem_acceptance(result: dict, check_p: int) -> None:
    """PR-7 acceptance at P=check_p on the --mem-budget axis: unlimited
    budget is bit-identical to the legacy store, the tightest budget
    produces real tier traffic with deterministic per-tier counts, and
    the headroom-aware greendygnn beats the best static fleet on total
    energy under that pressure."""
    mem = result.get("mem", {}).get(check_p)
    assert mem is not None, (
        f"--check with --mem-budget needs P={check_p} in --workers"
    )
    assert mem["unlimited_parity"], (
        "an unlimited MemoryBudget must be report-digest-identical to "
        "the legacy in-RAM store"
    )
    tight = min(mem["levels"], key=mem["levels"].get)
    rows = mem["rows"][tight]
    tc = rows["greendygnn"]["tier_counts"]
    assert tc and tc["block_fetches"] > 0 and tc["evictions"] > 0, (
        f"'{tight}' budget produced no tier traffic: {tc}"
    )
    assert rows["greendygnn"]["deterministic"], (
        "paired greendygnn runs under the tight budget disagreed on "
        "digest or per-tier counts"
    )
    e_ad = rows["greendygnn"]["total_kj"]
    statics = [
        rows[m]["total_kj"] for m in STATIC_METHODS if m in rows
    ]
    assert statics, "--check needs at least one static method"
    print(f"--check mem @ P={check_p}: headroom-aware greendygnn "
          f"{e_ad:.3f} kJ vs best static {min(statics):.3f} kJ under "
          f"'{tight}' budget ({tc['evictions']} evictions, "
          f"{tc['host_hits']} host hits, {tc['device_hits']} device hits)")
    assert e_ad < min(statics), (
        f"headroom-aware greendygnn ({e_ad:.3f} kJ) must beat the best "
        f"static fleet ({min(statics):.3f} kJ) under the '{tight}' "
        f"budget at P={check_p}"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="reddit")
    ap.add_argument("--batch", type=int, default=1000)
    ap.add_argument("--steps", type=int, default=96,
                    help="total train steps per run (bounds runtime)")
    ap.add_argument("--steps-per-epoch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", default="2,4,8",
                    help="comma list of cluster sizes P (n_parts = P)")
    ap.add_argument("--methods",
                    default="dgl,bgl,static_w,greendygnn_queue,greendygnn")
    ap.add_argument("--sync", default="allreduce",
                    choices=("allreduce", "reduce_scatter", "none"))
    ap.add_argument("--hot-rate", type=float, default=0.35,
                    help="hot_owner: partition-0 NIC rate multiplier")
    ap.add_argument("--slow-factor", type=float, default=1.5,
                    help="slow_worker: rank-0 t_base multiplier")
    ap.add_argument("--demand-bias", type=float, default=0.6,
                    help="demand_skew: share of globally-hot nodes "
                         "pre-assigned to partition 0")
    ap.add_argument("--iterations", type=int, default=6000,
                    help="DQN training budget for the greendygnn policies")
    ap.add_argument("--force", action="store_true",
                    help="retrain the policies even if cached")
    ap.add_argument("--mixture", action="store_true",
                    help="add the per-rank policy-mixture axis "
                         "(ClusterConfig.methods) under slow_worker")
    ap.add_argument("--mem-budget", default="",
                    help="comma list of tiered host-budget levels: named "
                         "presets (tight, loose) or fractions of the "
                         "graph's feature bytes (e.g. 0.15)")
    ap.add_argument("--chunk-rows", type=int, default=256,
                    help="host-tier block granularity (feature rows)")
    ap.add_argument("--trace", action="store_true",
                    help="capture a greentrace payload per cell (written "
                         "under results/bench/traces/, reconciled)")
    ap.add_argument("--check", action="store_true",
                    help="assert the PR-5 acceptance at --check-p (and "
                         "the PR-7 mem gates when --mem-budget is set)")
    ap.add_argument("--check-p", type=int, default=4)
    args = ap.parse_args()

    result = run_sweep(args)
    path = save_json("cluster_sweep", result)
    print(f"\nwrote {path}")
    if args.check:
        check_acceptance(result, args.check_p)
        if args.mem_budget:
            check_mem_acceptance(result, args.check_p)


if __name__ == "__main__":
    main()
