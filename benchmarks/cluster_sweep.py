"""Emergent-vs-injected congestion across cluster sizes P in {2, 4, 8}.

For each P, every partition runs a live trainer over ONE shared
requester-aware fabric (``repro.train.cluster``), and methods
dgl / bgl / static (static_w) / greendygnn are compared on *cluster-total*
energy under two families of scenarios:

  emergent (NO background overlay — congestion comes only from the P
  trainers' real traffic):
    clean         symmetric cluster; contention = P-way NIC sharing
    hot_owner     partition 0's NIC at a fraction of the base rate — a
                  hot/slow feature owner; every worker's misses to it
                  incast-collapse at that NIC
    slow_worker   rank 0 computes slower (t_base x) — a straggler whose
                  barrier drag and lagging rebuilds feed back into peers

  injected (the PR-2 background overlays, now *on top of* the emergent
  traffic): bursty_markov, incast

    PYTHONPATH=src python benchmarks/cluster_sweep.py --steps 96
    PYTHONPATH=src python benchmarks/cluster_sweep.py --workers 4 --check

``--check`` asserts the PR-4 acceptance at P=4: the cluster run exhibits
emergent queueing (fabric queue_s > 0 on every no-overlay scenario) and
greendygnn beats the BEST static policy (min over dgl/bgl/static_w) on
cluster-total energy under at least two emergent scenarios.
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

try:  # repo root (python -m benchmarks.cluster_sweep / python benchmarks/..)
    from benchmarks.common import base_cfg, save_json
except ImportError:  # cwd = benchmarks/
    from common import base_cfg, save_json

from repro.train import gnn_trainer as gt
from repro.train import policy as pol
from repro.train.cluster import (
    ClusterConfig,
    build_cluster_traces,
    run_cluster,
)

STATIC_METHODS = ("dgl", "bgl", "static_w")
METHOD_LABEL = {"static_w": "static"}
INJECTED = ("bursty_markov", "incast")


def emergent_scenarios(n_parts: int, hot_rate: float, slow_factor: float):
    """Name -> (fabric scenario, ClusterConfig physics kwargs)."""
    hot = np.ones(n_parts)
    hot[0] = hot_rate
    slow = np.ones(n_parts)
    slow[0] = slow_factor
    return {
        "clean": ("clean", {}),
        "hot_owner": ("clean", {"link_rate_scale": tuple(hot)}),
        "slow_worker": ("clean", {"compute_scale": tuple(slow)}),
    }


def get_q_fn(cfg0, bundle, iterations: int, force: bool):
    """Table-calibrated Double-DQN policy for one cluster size.

    The controller's obs/action spaces are sized by n_owners = P - 1, so
    each P gets its own calibration + checkpoint (``qnet_cluster_p<P>``).
    """
    P = cfg0.n_parts
    table = pol.calibrate_table_from_bundle(bundle, cfg0)
    q_fn, _ = pol.get_or_train_policy(
        pol.make_params_pool([table]), name=f"qnet_cluster_p{P}",
        iterations=iterations, force=force, n_owners=P - 1,
    )
    return q_fn


def run_sweep(args) -> dict:
    steps_per_epoch = args.steps_per_epoch
    n_epochs = max(args.steps // steps_per_epoch, 2)
    methods = args.methods.split(",")
    worker_counts = [int(p) for p in args.workers.split(",")]

    out: dict = {"rows": {}, "dataset": args.dataset, "batch": args.batch,
                 "n_epochs": n_epochs, "steps_per_epoch": steps_per_epoch,
                 "seed": args.seed, "sync": args.sync}
    for P in worker_counts:
        cfg0 = dataclasses.replace(
            base_cfg(args.dataset, args.batch),
            n_parts=P, n_epochs=n_epochs, steps_per_epoch=steps_per_epoch,
            seed=args.seed,
        )
        print(f"\n=== P={P}: building {P} per-partition traces...",
              flush=True)
        bundles = build_cluster_traces(cfg0, P)
        q_fn = None
        if any(m.startswith("greendygnn") for m in methods):
            q_fn = get_q_fn(cfg0, bundles[0], args.iterations, args.force)

        scenarios = dict(
            emergent_scenarios(P, args.hot_rate, args.slow_factor)
        )
        for sc in INJECTED:
            scenarios[f"injected:{sc}"] = (sc, {})

        out["rows"][P] = {}
        header = f"{'scenario':>22} " + "".join(
            f"{METHOD_LABEL.get(m, m):>12}" for m in methods
        )
        print(f"cluster-total energy [kJ], P={P} workers, "
              f"sync={args.sync}\n{header}")
        for name, (fabric_sc, physics) in scenarios.items():
            out["rows"][P][name] = {}
            cells = []
            for m in methods:
                cfg_m = dataclasses.replace(
                    cfg0, method=m, scenario=fabric_sc,
                    q_fn=q_fn if m.startswith("greendygnn") else None,
                )
                rep = run_cluster(
                    cfg_m,
                    ClusterConfig(n_workers=P, sync=args.sync, **physics),
                    trace_bundles=bundles,
                )
                t = rep.totals_kj()
                out["rows"][P][name][m] = {
                    "total_kj": t["total_kj"],
                    "gpu_kj": t["gpu_kj"],
                    "cpu_kj": t["cpu_kj"],
                    "wall_s": t["wall_s"],
                    "queue_s": rep.total_queue_s,
                    "hit_rate": float(np.mean([
                        float(r.hit_rate_per_epoch.mean())
                        for r in rep.results
                    ])),
                    "per_worker": rep.per_worker(),
                }
                cells.append(f"{t['total_kj']:12.3f}")
            q = out["rows"][P][name][methods[0]]["queue_s"]
            print(f"{name:>22} " + "".join(cells) + f"   (queue {q:.3f}s)")
    return out


def check_acceptance(result: dict, check_p: int, adaptive: str) -> None:
    """PR-4 acceptance: emergent congestion + adaptive wins at P=check_p."""
    rows = result["rows"].get(check_p)
    assert rows is not None, f"--check needs P={check_p} in --workers"
    emergent = [n for n in rows if not n.startswith("injected:")]
    for name in emergent:
        q = rows[name][adaptive]["queue_s"]
        assert q > 0, f"no emergent queueing under {name} (queue_s={q})"
    wins = []
    for name in emergent:
        e_ad = rows[name][adaptive]["total_kj"]
        statics = [
            rows[name][m]["total_kj"] for m in STATIC_METHODS
            if m in rows[name]
        ]
        assert statics, "--check needs at least one static method"
        if e_ad < min(statics):
            wins.append((name, e_ad, min(statics)))
    print(f"\n--check @ P={check_p}: {adaptive} beats best-static on "
          f"{len(wins)}/{len(emergent)} emergent scenarios: "
          + ", ".join(f"{n} ({a:.3f} < {s:.3f} kJ)" for n, a, s in wins))
    assert len(wins) >= 2, (
        f"{adaptive} must beat the best static policy on >= 2 emergent "
        f"scenarios at P={check_p}, won only {len(wins)}"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="reddit")
    ap.add_argument("--batch", type=int, default=1000)
    ap.add_argument("--steps", type=int, default=96,
                    help="total train steps per run (bounds runtime)")
    ap.add_argument("--steps-per-epoch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", default="2,4,8",
                    help="comma list of cluster sizes P (n_parts = P)")
    ap.add_argument("--methods",
                    default="dgl,bgl,static_w,greendygnn")
    ap.add_argument("--sync", default="allreduce",
                    choices=("allreduce", "reduce_scatter", "none"))
    ap.add_argument("--hot-rate", type=float, default=0.35,
                    help="hot_owner: partition-0 NIC rate multiplier")
    ap.add_argument("--slow-factor", type=float, default=1.5,
                    help="slow_worker: rank-0 t_base multiplier")
    ap.add_argument("--iterations", type=int, default=6000,
                    help="DQN training budget for the greendygnn policy")
    ap.add_argument("--force", action="store_true",
                    help="retrain the policy even if cached")
    ap.add_argument("--check", action="store_true",
                    help="assert the PR-4 acceptance at --check-p")
    ap.add_argument("--check-p", type=int, default=4)
    args = ap.parse_args()

    result = run_sweep(args)
    path = save_json("cluster_sweep", result)
    print(f"\nwrote {path}")
    if args.check:
        adaptive = next(
            (m for m in args.methods.split(",")
             if m not in STATIC_METHODS), None,
        )
        assert adaptive, "--check needs an adaptive method in --methods"
        check_acceptance(result, args.check_p, adaptive)


if __name__ == "__main__":
    main()
