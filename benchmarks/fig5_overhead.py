"""Fig. 5: congestion overhead relative to each method's own clean baseline.

Claims: Default DGL suffers ~30-50% overhead; RapidGNN absorbs part of it;
GreenDyGNN the least on every dataset.
"""
from __future__ import annotations

from benchmarks.common import DATASETS, METHODS, fmt_row, save_json, sweep


def main(batch: int = 2000) -> list[str]:
    sw = sweep()
    rows, table = [], []
    for ds in DATASETS:
        entry = {"dataset": ds}
        for m in METHODS:
            cong = sw.totals(ds, batch, m, congested=True)["total_kj"]
            clean = sw.totals(ds, batch, m, congested=False)["total_kj"]
            entry[m] = round(100 * (cong / clean - 1), 2)
        table.append(entry)
        rows.append(fmt_row(
            f"fig5/{ds}/overhead_pct",
            "|".join(f"{m}={entry[m]:.1f}" for m in METHODS),
        ))
        best = min((m for m in METHODS), key=lambda m: entry[m])
        rows.append(fmt_row(
            f"fig5/{ds}/lowest_overhead", best,
            "paper: greendygnn lowest on every dataset",
        ))
    save_json("fig5_overhead", table)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
