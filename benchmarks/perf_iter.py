"""Perf-iteration harness: re-lower a cell under config/rule variants and
report the roofline-term deltas (the hypothesis -> change -> measure loop).

    PYTHONPATH=src python benchmarks/perf_iter.py <cell> <variant>
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
import dataclasses
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import get_arch
from repro.launch import roofline as rl
from repro.launch.cell import build_cell, cell_rules
from repro.launch.mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "perf")


def measure(arch_id, shape, config_patch=None, rule_patch=None, label="base"):
    arch = get_arch(arch_id)
    if config_patch:
        base_make = arch.make_config

        def patched(*a, **k):
            return dataclasses.replace(base_make(*a, **k), **config_patch)

        arch = dataclasses.replace(arch, make_config=patched)
    if rule_patch:
        arch = dataclasses.replace(
            arch, rule_overrides={**arch.rule_overrides, **rule_patch}
        )
    mesh = make_production_mesh(multi_pod=False)
    cell = build_cell(arch, shape, mesh)
    t0 = time.time()
    compiled = (
        jax.jit(cell["step_fn"], in_shardings=cell["in_shardings"])
        .lower(*cell["args"]).compile()
    )
    cost = compiled.cost_analysis()
    if not isinstance(cost, dict):
        cost = cost[0]
    factor = rl.loop_factor(arch_id, shape)
    if config_patch and "grad_accum" in config_patch:
        cfg = arch.make_config()
        factor = max(cfg.n_scan_layers, 1) * config_patch["grad_accum"]
    terms = rl.roofline_terms(cost, compiled.as_text(), factor)
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 1e9
    rec = {
        "cell": f"{arch_id}/{shape}", "variant": label,
        "compile_s": round(time.time() - t0, 1),
        "peak_gb": round(peak, 2),
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": terms["dominant"],
        "fraction": round(terms["roofline_fraction"], 4),
        "collectives": {k: round(v / 1e9, 2)
                        for k, v in terms["collective_breakdown"].items()},
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(
            RESULTS, f"{arch_id}__{shape}__{label}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec))
    return rec


VARIANTS = {
    # --- cell A: deepseek-v2-236b x train_4k (most collective-bound) ------
    ("deepseek-v2-236b", "train_4k"): {
        "base": ({}, {}),
        # H1: grad-accum re-gathers FSDP-sharded weights once per microbatch
        #     -> fewer microbatches cut weight all-gathers ~4x (memory peak
        #     rises with the bigger microbatch)
        "accum2": ({"grad_accum": 2}, {}),
        # H2: ZeRO-1 for expert weights: keep them replicated across data
        #     (sharded over experts/model only) -> no per-use all-gather at
        #     all; optimizer state grows per device
        "zero1": ({"grad_accum": 2}, {"embed_rows": None}),
        # H3: bigger attention KV blocks -> fewer scan steps re-reading Q
        "blockk4096": ({"grad_accum": 2, "attn_block_k": 4096}, {}),
    },
    # --- cell B: nequip x ogb_products (paper-domain, collective-bound) ---
    ("nequip", "ogb_products"): {
        "base": ({}, {}),
        # H1: node features gathered across ALL axes per edge chunk; keep
        #     node arrays sharded over data only -> model-axis gathers vanish
        "nodes_data_only": ({}, {"nodes": "data"}),
        # H2: bigger edge chunks -> fewer scan iterations (less re-gather),
        #     more VMEM per chunk
        "chunk2m": ("edge_chunk_2m", {}),
        # H3: combine both
        "combined": ("edge_chunk_2m", {"nodes": "data"}),
    },
    # --- cell C: qwen3-1.7b x train_4k (baseline best fraction) -----------
    ("qwen3-1.7b", "train_4k"): {
        "base": ({}, {}),
        # H1: the 1.7B weights fit per-device: drop FSDP (replicate rows
        #     over data) -> param all-gathers vanish, grad all-reduce stays
        "replicated": ({}, {"embed_rows": None}),
        # H2: no microbatching (batch fits once FSDP gathers are gone)
        "accum1": ({"grad_accum": 1}, {"embed_rows": None}),
        # H3: coarser CE chunks -> fewer lm-head passes
        "chunk2048": ({"grad_accum": 1, "loss_chunk": 2048},
                      {"embed_rows": None}),
    },
}


def main():
    cell = (sys.argv[1], sys.argv[2])
    variants = VARIANTS[cell]
    which = sys.argv[3:] or list(variants)
    for label in which:
        patch, rules = variants[label]
        if patch == "edge_chunk_2m":
            patch = {"edge_chunk": 2_097_152}
        measure(cell[0], cell[1], patch, rules, label)


if __name__ == "__main__":
    main()
