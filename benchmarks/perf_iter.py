"""Perf-iteration harness.

Two modes share this entry point:

SAGE measured-lane bench (default, no positional args)::

    PYTHONPATH=src python benchmarks/perf_iter.py --steps 8 --check

Runs the real jitted GraphSAGE step (``repro.train.compute``) through the
trainer's ``compute="measured"`` lane and emits
``results/bench/perf_iter.json`` with

  * per-step wall times (warm-up compile excluded by the engine),
  * the roofline terms of the compiled SAGE step
    (``repro.launch.roofline.roofline_terms`` over the AOT executable's
    cost analysis + HLO text) and the achieved fraction of that bound,
  * an aggregation microbenchmark — the engine's compiled block-sparse
    path vs a jitted per-edge segment-sum reference at SAGE-layer-like
    many-to-few shapes (min-of-k timing),
  * the modeled-vs-measured energy delta after ``calibrate_compute``
    refits ``t_base`` from the measured samples.

``--check`` turns the bench into a gate: the block path must not lose to
the segment-sum reference at any benchmark shape, and re-running the
modeled lane with the calibrated ``t_base`` must land within tolerance
of the measured run's compute energy.

Legacy cell-variant mode (positional args, unchanged)::

    PYTHONPATH=src python benchmarks/perf_iter.py <cell> <variant>

re-lowers a launch cell under config/rule variants and reports the
roofline-term deltas (the hypothesis -> change -> measure loop).
"""
import os
import sys

_LEGACY = len(sys.argv) > 1 and not sys.argv[1].startswith("-")
if _LEGACY:
    # the cell variants lower against a production mesh of virtual hosts;
    # the SAGE bench times real compute and must NOT fragment the CPU
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import dataclasses
import json
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "perf")
BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "results", "bench", "perf_iter.json"
)


# ---------------------------------------------------------------------------
# SAGE measured-lane bench
# ---------------------------------------------------------------------------

# many-to-few SAGE-layer-like aggregation shapes: (n_dst, n_src, n_edges,
# n_feat) — dense enough per 128x128 block that the block-matmul path is
# the right algorithm, which is exactly the regime the engine runs in
AGG_SHAPES = (
    (256, 2048, 120_000, 64),
    (512, 4096, 400_000, 128),
)
_TIMING_REPS = 5


def _time_compiled(fn, *args) -> float:
    """min-of-k wall time of an already-warm jitted callable [s]."""
    best = float("inf")
    for _ in range(_TIMING_REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_aggregation(tile: int = 128) -> list[dict]:
    """Engine block path vs jitted per-edge segment-sum reference."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.segment_mm import block_spmm_xla, to_block_sparse

    from functools import partial

    @partial(jax.jit, static_argnames=("n_dst",))
    def _ref(x, src, dst, w, n_dst):
        return jax.ops.segment_sum(
            x[src] * w[:, None], dst, num_segments=n_dst
        )

    out = []
    for n_dst, n_src, n_edges, n_feat in AGG_SHAPES:
        rng = np.random.default_rng(0)
        src = rng.integers(0, n_src, n_edges).astype(np.int32)
        dst = rng.integers(0, n_dst, n_edges).astype(np.int32)
        x = rng.standard_normal((n_src, n_feat)).astype(np.float32)
        w = np.ones(n_edges, np.float32)

        rows, cols, blocks, ndb, n_src_pad = to_block_sparse(
            src, dst, n_dst, n_src, tile, tile, edge_weight=w
        )
        x_pad = np.zeros((n_src_pad, n_feat), np.float32)
        x_pad[:n_src] = x
        rows_j, cols_j = jnp.asarray(rows), jnp.asarray(cols)
        blocks_j, x_j = jnp.asarray(blocks), jnp.asarray(x_pad)

        def _block(r, c, b, xp, ndb=ndb):
            return block_spmm_xla(r, c, b, xp, ndb, tile, tile)

        # warm both once, assert parity, then time
        y_block = np.asarray(_block(rows_j, cols_j, blocks_j, x_j))[:n_dst]
        srj, dsj = jnp.asarray(src), jnp.asarray(dst)
        wj, xj = jnp.asarray(w), jnp.asarray(x)
        y_ref = np.asarray(_ref(xj, srj, dsj, wj, n_dst))
        max_diff = float(np.max(np.abs(y_block - y_ref)))
        scale = float(np.max(np.abs(y_ref))) or 1.0
        block_s = _time_compiled(_block, rows_j, cols_j, blocks_j, x_j)
        ref_s = _time_compiled(_ref, xj, srj, dsj, wj, n_dst)
        out.append({
            "shape": [n_dst, n_src, n_edges, n_feat],
            "block_ms": round(block_s * 1e3, 4),
            "segment_sum_ms": round(ref_s * 1e3, 4),
            "speedup": round(ref_s / block_s, 3),
            "rel_diff": max_diff / scale,
        })
    return out


def bench_sage(args) -> dict:
    import numpy as np

    from repro.core import calibration as cal
    from repro.launch import roofline as rl
    from repro.train import gnn_trainer as gt
    from repro.train.compute import ComputeEngine

    cfg = gt.RunConfig(
        method="static_w", dataset=args.dataset, batch_size=args.batch,
        n_epochs=1, steps_per_epoch=args.steps, scenario="clean",
        seed=args.seed, compute="measured",
        grad_compression=args.grad_compression,
    )
    bundle = gt.build_trace(cfg)

    # measured lane end to end: the engine's wall times feed the meter
    res_meas = gt.run(cfg, bundle)
    rep = res_meas.compute_report
    step_s = np.asarray(rep["step_s"], np.float64)
    edges = np.asarray(rep["step_edges"], np.float64)

    # refit t_base from the measured samples, replay the modeled lane
    params_cal, fit = cal.calibrate_compute(edges, step_s)
    cfg_mod = dataclasses.replace(
        cfg, compute="modeled",
        params=dataclasses.replace(cfg.params, t_base=params_cal.t_base),
    )
    res_mod = gt.run(cfg_mod, bundle)
    gpu_meas = float(res_meas.meter.gpu_j)
    gpu_mod = float(res_mod.meter.gpu_j)
    energy_delta = abs(gpu_meas - gpu_mod) / max(gpu_mod, 1e-12)

    # roofline of the compiled step: one standalone engine, one step, then
    # read the AOT executable's cost analysis + HLO text
    graph, _owner, _traces, mbs = bundle
    eng = ComputeEngine(graph, cfg)
    mb = mbs[0][0]
    eng.step(
        mb, np.asarray(graph.features[mb.input_nodes], np.float32),
        key=(0, 0),
    )
    exe = next(iter(eng._exec.values()))
    cost = exe.cost_analysis()
    if not isinstance(cost, dict):
        cost = cost[0]
    terms = rl.roofline_terms(cost, exe.as_text(), 1.0)
    bound_s = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"]
    )
    mean_step = float(step_s.mean())

    agg = bench_aggregation()

    return {
        "backend": jax.default_backend(),
        "agg_impl": rep["agg_impl"],
        "grad_compression": rep["grad_compression"],
        "sync_wire_bytes": rep["sync_wire_bytes"],
        "steps": int(rep["n_steps"]),
        "step_wall_s": [round(float(t), 6) for t in step_s],
        "mean_step_s": round(mean_step, 6),
        "min_step_s": round(float(step_s.min()), 6),
        "compile_s": round(float(rep["compile_s"]), 3),
        "parity_max_diff": rep["parity_max_diff"],
        "roofline": {
            "compute_s": terms["compute_s"],
            "memory_s": terms["memory_s"],
            "collective_s": terms["collective_s"],
            "dominant": terms["dominant"],
            "roofline_fraction": round(terms["roofline_fraction"], 4),
            "bound_s": bound_s,
            # wall time over the ideal-hardware bound: >> 1 on CPU, -> 1
            # as the step approaches the v5e roofline
            "achieved_over_bound": round(mean_step / max(bound_s, 1e-12), 2),
        },
        "energy": {
            "measured_gpu_j": gpu_meas,
            "modeled_gpu_j_calibrated": gpu_mod,
            "rel_delta": energy_delta,
            "t_base_calibrated_s": float(params_cal.t_base),
            "fit_r2": float(fit.r2),
        },
        "aggregation": agg,
    }


def run_checks(rec: dict, tol_energy: float = 0.05) -> bool:
    ok = True
    for row in rec["aggregation"]:
        good = row["block_ms"] <= row["segment_sum_ms"]
        parity = row["rel_diff"] <= 1e-4
        status = "OK " if (good and parity) else "FAIL"
        print(f"[perf_iter] {status} agg {tuple(row['shape'])}: "
              f"block {row['block_ms']:.3f} ms vs segment-sum "
              f"{row['segment_sum_ms']:.3f} ms "
              f"(x{row['speedup']:.2f}, rel diff {row['rel_diff']:.1e})")
        ok &= good and parity
    delta = rec["energy"]["rel_delta"]
    e_ok = delta <= tol_energy
    print(f"[perf_iter] {'OK ' if e_ok else 'FAIL'} energy: "
          f"measured {rec['energy']['measured_gpu_j']:.3f} J vs "
          f"calibrated-modeled "
          f"{rec['energy']['modeled_gpu_j_calibrated']:.3f} J "
          f"(rel delta {delta:.2e} <= {tol_energy})")
    ok &= e_ok
    return ok


def sage_main(argv) -> int:
    p = argparse.ArgumentParser(description="SAGE measured-lane bench")
    p.add_argument("--steps", type=int, default=16)
    p.add_argument("--batch", type=int, default=600)
    p.add_argument("--dataset", default="reddit")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--grad-compression", default="none",
                   choices=("none", "int8", "topk"))
    p.add_argument("--check", action="store_true",
                   help="gate: block path <= segment-sum reference and "
                        "modeled-vs-measured energy within tolerance")
    p.add_argument("--json", default=BENCH_JSON,
                   help="output path (default results/bench/perf_iter.json)")
    args = p.parse_args(argv)

    rec = bench_sage(args)
    os.makedirs(os.path.dirname(args.json), exist_ok=True)
    with open(args.json, "w") as f:
        json.dump(rec, f, indent=1)
        f.write("\n")
    print(f"[perf_iter] wrote {os.path.relpath(args.json)}")
    print(json.dumps({k: rec[k] for k in
                      ("backend", "agg_impl", "mean_step_s", "compile_s")}))
    if args.check:
        return 0 if run_checks(rec) else 1
    return 0


# ---------------------------------------------------------------------------
# Legacy cell-variant mode
# ---------------------------------------------------------------------------

def measure(arch_id, shape, config_patch=None, rule_patch=None, label="base"):
    from repro.configs.registry import get_arch
    from repro.launch import roofline as rl
    from repro.launch.cell import build_cell
    from repro.launch.mesh import make_production_mesh

    arch = get_arch(arch_id)
    if config_patch:
        base_make = arch.make_config

        def patched(*a, **k):
            return dataclasses.replace(base_make(*a, **k), **config_patch)

        arch = dataclasses.replace(arch, make_config=patched)
    if rule_patch:
        arch = dataclasses.replace(
            arch, rule_overrides={**arch.rule_overrides, **rule_patch}
        )
    mesh = make_production_mesh(multi_pod=False)
    cell = build_cell(arch, shape, mesh)
    t0 = time.time()
    compiled = (
        jax.jit(cell["step_fn"], in_shardings=cell["in_shardings"])
        .lower(*cell["args"]).compile()
    )
    cost = compiled.cost_analysis()
    if not isinstance(cost, dict):
        cost = cost[0]
    factor = rl.loop_factor(arch_id, shape)
    if config_patch and "grad_accum" in config_patch:
        cfg = arch.make_config()
        factor = max(cfg.n_scan_layers, 1) * config_patch["grad_accum"]
    terms = rl.roofline_terms(cost, compiled.as_text(), factor)
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 1e9
    rec = {
        "cell": f"{arch_id}/{shape}", "variant": label,
        "compile_s": round(time.time() - t0, 1),
        "peak_gb": round(peak, 2),
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": terms["dominant"],
        "fraction": round(terms["roofline_fraction"], 4),
        "collectives": {k: round(v / 1e9, 2)
                        for k, v in terms["collective_breakdown"].items()},
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(
            RESULTS, f"{arch_id}__{shape}__{label}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec))
    return rec


VARIANTS = {
    # --- cell A: deepseek-v2-236b x train_4k (most collective-bound) ------
    ("deepseek-v2-236b", "train_4k"): {
        "base": ({}, {}),
        # H1: grad-accum re-gathers FSDP-sharded weights once per microbatch
        #     -> fewer microbatches cut weight all-gathers ~4x (memory peak
        #     rises with the bigger microbatch)
        "accum2": ({"grad_accum": 2}, {}),
        # H2: ZeRO-1 for expert weights: keep them replicated across data
        #     (sharded over experts/model only) -> no per-use all-gather at
        #     all; optimizer state grows per device
        "zero1": ({"grad_accum": 2}, {"embed_rows": None}),
        # H3: bigger attention KV blocks -> fewer scan steps re-reading Q
        "blockk4096": ({"grad_accum": 2, "attn_block_k": 4096}, {}),
    },
    # --- cell B: nequip x ogb_products (paper-domain, collective-bound) ---
    ("nequip", "ogb_products"): {
        "base": ({}, {}),
        # H1: node features gathered across ALL axes per edge chunk; keep
        #     node arrays sharded over data only -> model-axis gathers vanish
        "nodes_data_only": ({}, {"nodes": "data"}),
        # H2: bigger edge chunks -> fewer scan iterations (less re-gather),
        #     more VMEM per chunk
        "chunk2m": ("edge_chunk_2m", {}),
        # H3: combine both
        "combined": ("edge_chunk_2m", {"nodes": "data"}),
    },
    # --- cell C: qwen3-1.7b x train_4k (baseline best fraction) -----------
    ("qwen3-1.7b", "train_4k"): {
        "base": ({}, {}),
        # H1: the 1.7B weights fit per-device: drop FSDP (replicate rows
        #     over data) -> param all-gathers vanish, grad all-reduce stays
        "replicated": ({}, {"embed_rows": None}),
        # H2: no microbatching (batch fits once FSDP gathers are gone)
        "accum1": ({"grad_accum": 1}, {"embed_rows": None}),
        # H3: coarser CE chunks -> fewer lm-head passes
        "chunk2048": ({"grad_accum": 1, "loss_chunk": 2048},
                      {"embed_rows": None}),
    },
}


def legacy_main():
    cell = (sys.argv[1], sys.argv[2])
    variants = VARIANTS[cell]
    which = sys.argv[3:] or list(variants)
    for label in which:
        patch, rules = variants[label]
        if patch == "edge_chunk_2m":
            patch = {"edge_chunk": 2_097_152}
        measure(cell[0], cell[1], patch, rules, label)


if __name__ == "__main__":
    if _LEGACY:
        legacy_main()
    else:
        sys.exit(sage_main(sys.argv[1:]))
