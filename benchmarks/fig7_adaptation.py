"""Fig. 7: RL agent behavior — rebuild window chosen per epoch vs the
static baseline, and cache hit rates per epoch.

Claims: clean warmup settles near W=16; congestion onset drives W down
toward 4-10; adaptive hit rate >= static's during congested phases.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_row, save_json, sweep


def main(dataset: str = "ogbn-papers100m", batch: int = 2000) -> list[str]:
    sw = sweep()
    ours = sw.run(dataset, batch, "greendygnn", congested=True)
    static = sw.run(dataset, batch, "rapidgnn", congested=True)
    congested_epochs = np.where(ours.sigma_trace.max(axis=1) > 1.05)[0]
    clean_epochs = np.where(ours.sigma_trace.max(axis=1) <= 1.05)[0]
    clean_epochs = clean_epochs[clean_epochs >= 2]  # skip warmup

    w_clean = float(ours.window_per_epoch[clean_epochs].mean())
    w_cong = float(ours.window_per_epoch[congested_epochs].mean())
    h_ours = float(ours.hit_rate_per_epoch[congested_epochs].mean())
    h_stat = float(static.hit_rate_per_epoch[congested_epochs].mean())

    table = {
        "window_per_epoch": ours.window_per_epoch.tolist(),
        "hit_ours": ours.hit_rate_per_epoch.tolist(),
        "hit_static": static.hit_rate_per_epoch.tolist(),
        "sigma_max": ours.sigma_trace.max(axis=1).tolist(),
    }
    save_json("fig7_adaptation", table)
    return [
        fmt_row("fig7/mean_W_clean", f"{w_clean:.1f}", "paper: settles ~16"),
        fmt_row("fig7/mean_W_congested", f"{w_cong:.1f}",
                "paper: drops toward 4-10"),
        fmt_row("fig7/W_shrinks_under_congestion", w_cong < w_clean),
        fmt_row("fig7/hit_ours_vs_static_congested",
                f"{h_ours:.3f}_vs_{h_stat:.3f}",
                "paper: adaptive reaches higher hit peaks"),
    ]


if __name__ == "__main__":
    print("\n".join(main()))
