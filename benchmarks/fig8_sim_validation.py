"""Fig. 8: calibrated-simulator validation across a (W, delta) grid.

The tabular simulator's predicted step time is compared against the
trace-driven trainer ("the cluster") at every (rebuild window, injected
delay) grid point. Paper reports mean 2.8% error, <5% across the range.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import base_cfg, fmt_row, save_json, sweep
from repro.core import table_sim as ts
from repro.train import gnn_trainer as gt
from repro.train import policy as pol

GRID_W = [1, 2, 4, 8, 16, 32, 64]
GRID_DELTA = [0.0, 5.0, 10.0, 15.0, 20.0, 25.0]


def main(dataset: str = "reddit", batch: int = 2000) -> list[str]:
    sw = sweep()
    bundle = sw.trace(dataset, batch)
    cfg = base_cfg(dataset, batch)
    tp = pol.calibrate_table_from_bundle(bundle, cfg)

    from repro.core.cost_model import WINDOW_CHOICES

    errors, table = [], []
    for w in GRID_W:
        wi = WINDOW_CHOICES.index(w)
        for d in GRID_DELTA:
            # fixed_delta_ms congests EVERY owner link; the prediction must
            # model the same condition
            delta = jnp.asarray([d, d, d])
            t_pred, _, _ = ts.step_time_energy(
                tp, jnp.asarray(wi), jnp.asarray(0), delta
            )
            r = gt.run(
                dataclasses.replace(
                    cfg, method="static_w", static_window=w,
                    congested=d > 0, fixed_delta_ms=d or None, n_epochs=4,
                ),
                bundle,
            )
            t_meas = r.meter.wall_s / max(r.meter.n_steps, 1)
            err = abs(float(t_pred) - t_meas) / t_meas
            errors.append(err)
            table.append({"W": w, "delta_ms": d,
                          "pred_ms": float(t_pred) * 1e3,
                          "meas_ms": t_meas * 1e3,
                          "err_pct": 100 * err})

    mean_err = 100 * float(np.mean(errors))
    max_err = 100 * float(np.max(errors))
    save_json("fig8_sim_validation", table)
    return [
        fmt_row("fig8/mean_error_pct", f"{mean_err:.2f}", "paper: 2.8"),
        fmt_row("fig8/max_error_pct", f"{max_err:.2f}",
                "paper: below 5 across the range"),
        fmt_row("fig8/grid_points", len(table)),
    ]


if __name__ == "__main__":
    print("\n".join(main()))
