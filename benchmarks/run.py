"""Benchmark driver — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark plus a claims summary.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (
        fig1_rpc_energy,
        fig5_overhead,
        fig6_clean,
        fig7_adaptation,
        fig8_sim_validation,
        fig9_cumulative,
        pipeline_overlap,
        roofline_report,
        table1_energy,
        table2_ablation,
    )

    modules = [
        ("fig1_rpc_energy", fig1_rpc_energy),
        ("table1_energy", table1_energy),
        ("fig5_overhead", fig5_overhead),
        ("fig6_clean", fig6_clean),
        ("fig7_adaptation", fig7_adaptation),
        ("fig8_sim_validation", fig8_sim_validation),
        ("fig9_cumulative", fig9_cumulative),
        ("table2_ablation", table2_ablation),
        ("pipeline_overlap", pipeline_overlap),
        ("roofline_report", roofline_report),
    ]
    print("name,value,derived")
    for name, mod in modules:
        t0 = time.time()
        try:
            rows = mod.main()
        except Exception as e:  # noqa: BLE001
            rows = [f"{name}/ERROR,{type(e).__name__},{e}"]
        for row in rows:
            print(row, flush=True)
        print(f"{name}/wall_s,{time.time() - t0:.1f},", flush=True)


if __name__ == "__main__":
    main()
