"""Cross-scenario policy gauntlet: train-env x eval-scenario energy matrix.

Trains one Double-DQN per training environment (analytic parametric sim,
trace-calibrated tabular sim, queue-aware scenario-conditioned sim) and
evaluates every policy — plus the dgl / bgl / static baselines — on every
net-fabric scenario through the trace-driven trainer. This is the paper's
headline claim made measurable: a policy trained in a calibrated simulator
with domain-randomized congestion must transfer to dynamics it was not
hand-tuned for. The JSON output makes policy-quality drift trackable
between PRs (CI uploads it as a workflow artifact).

    PYTHONPATH=src python benchmarks/policy_gauntlet.py --steps 96 \
        --iterations 4000
    PYTHONPATH=src python benchmarks/policy_gauntlet.py --check   # acceptance

``--check`` asserts the ISSUE-3 acceptance criteria: the queue-sim-trained
policy is no worse than the analytic-sim-trained policy on every fabric
scenario, strictly better on bursty_markov and incast, and within 5% on
clean.
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

try:  # repo root (python -m benchmarks.policy_gauntlet / python benchmarks/..)
    from benchmarks.common import base_cfg, save_json
except ImportError:  # cwd = benchmarks/
    from common import base_cfg, save_json

from repro.core import cost_model as cm
from repro.net import ScenarioRegistry
from repro.train import gnn_trainer as gt
from repro.train import policy as pol

BASELINES = ["dgl", "bgl", "static_w"]
METHOD_LABEL = {"static_w": "static"}
TRAIN_ENVS = ["analytic", "table", "queue"]
# the two scenarios where queue-aware training must strictly win (--check)
MUST_WIN = ("bursty_markov", "incast")


def default_scenarios() -> list[str]:
    return [n for n in ScenarioRegistry.names() if ":" not in n]


def build_pools(args, cfg0, bundle) -> dict:
    """Per-env parameter pools. ``--quick`` skips Algorithm-1 calibration
    (benchmark-speed mode: published constants + the trace's true feature
    width); the table env always needs its trace replay."""
    pools = {}
    if "table" in args.train_envs:
        print("calibrating tabular Phase 2 (trace replay)...", flush=True)
        pools["table"] = pol.make_params_pool(
            [pol.calibrate_table_from_bundle(bundle, cfg0)]
        )
    if "analytic" in args.train_envs or "queue" in args.train_envs:
        if args.quick:
            from repro.graph.features import ShardedFeatureStore

            graph, owner, traces, _ = bundle
            store = ShardedFeatureStore(
                graph.features, owner, 0, cfg0.n_parts
            )
            # trace-derived scales without the Phase-2 stall-grid runs: the
            # REAL mean remote rows per step and bytes per row (these set
            # the queue sim's payload/backlog physics), with t_miss0
            # rescaled to keep the analytic env's calibrated R * t_miss0
            # product at its published operating point
            r_mean = float(np.mean(
                [len(store.remote_ids_of(t)) for ep in traces[:2] for t in ep]
            ))
            base = cm.CostModelParams()
            theta = base.replace(
                feature_bytes=store.bytes_per_row,
                remote_nodes=r_mean,
                t_miss0=float(base.t_miss0) * float(base.remote_nodes)
                / max(r_mean, 1.0),
            )
        else:
            print("calibrating analytic Phase 2 (Algorithm 1)...", flush=True)
            theta, _ = pol.calibrate_from_bundle(bundle, cfg0)
        analytic_pool = pol.make_params_pool([theta])
        for env in ("analytic", "queue"):
            if env in args.train_envs:
                pools[env] = analytic_pool
    return pools


def train_policies(args, pools, cfg0) -> dict:
    # Training episodes run the paper's 30-epoch horizon (scenario burst /
    # cycle timescales are run-length-relative in BOTH the training envs
    # and the eval fabric, so the congestion families line up at any eval
    # --steps budget). Matching the eval horizon instead sounds more
    # faithful but collapses every policy onto one or two post-warmup
    # decisions — too few to learn (or to measure) scenario-conditional
    # behavior.
    q_fns = {}
    for env in args.train_envs:
        print(f"training policy on env={env} "
              f"({args.iterations} iterations, "
              f"{args.train_epochs}x32-step episodes)...", flush=True)
        # every knob that changes the trained policy — training settings AND
        # the trace/calibration shape behind the params pool — is part of
        # the cache key, so reruns with different settings never reuse a
        # stale qnet
        name = (
            f"qnet_gauntlet_{args.dataset}_b{args.batch}"
            f"_t{args.steps}x{args.steps_per_epoch}_i{args.iterations}"
            f"_e{args.train_epochs}_n{args.n_envs}_s{args.seed}"
            + ("_quick" if args.quick else "")
        )
        q_fn, _ = pol.get_or_train_policy(
            pools[env], name=name,
            iterations=args.iterations, env=env, force=args.force,
            seed=args.seed, n_epochs=args.train_epochs, n_envs=args.n_envs,
        )
        q_fns[env] = q_fn
    return q_fns


def run_gauntlet(args, cfg0, bundle, q_fns) -> dict:
    scenarios = (
        args.scenarios.split(",") if args.scenarios else default_scenarios()
    )
    columns = BASELINES + [f"dqn_{e}" for e in args.train_envs]
    rows: dict = {}
    header = f"{'scenario':>16} " + "".join(
        f"{METHOD_LABEL.get(c, c):>13}" for c in columns
    )
    print("\ntotal energy [kJ] per scenario x policy")
    print(header)
    for sc in scenarios:
        rows[sc] = {}
        cells = []
        for col in columns:
            if col.startswith("dqn_"):
                cfg = dataclasses.replace(
                    cfg0, method="greendygnn", scenario=sc,
                    q_fn=q_fns[col[len("dqn_"):]],
                )
            else:
                cfg = dataclasses.replace(cfg0, method=col, scenario=sc)
            r = gt.run(cfg, bundle)
            t = r.totals()
            rows[sc][col] = {
                "total_kj": t["total_kj"],
                "cpu_kj": t["cpu_kj"],
                "gpu_kj": t["gpu_kj"],
                "wall_s": t["wall_s"],
                "hit_rate": float(r.hit_rate_per_epoch.mean()),
                "mean_window": float(r.window_per_epoch.mean()),
                "mean_sigma": float(r.sigma_trace.mean()),
            }
            cells.append(f"{t['total_kj']:13.3f}")
        print(f"{sc:>16} " + "".join(cells))
    return rows


def check_acceptance(rows: dict, tol_eq: float = 0.02,
                     tol_clean: float = 0.05) -> None:
    """ISSUE-3 acceptance: queue <= analytic everywhere (within ``tol_eq``),
    strictly better on MUST_WIN, clean parity within ``tol_clean``."""
    missing = [s for s in (*MUST_WIN, "clean") if s not in rows]
    if missing:
        raise SystemExit(
            "--check needs the clean and must-win scenarios evaluated; "
            "missing: " + ", ".join(missing)
        )
    failures = []
    for sc, cols in rows.items():
        if "dqn_queue" not in cols or "dqn_analytic" not in cols:
            raise SystemExit("--check needs both queue and analytic envs")
        q = cols["dqn_queue"]["total_kj"]
        a = cols["dqn_analytic"]["total_kj"]
        # clean is governed by its own (looser, one-sided) parity band below
        if sc != "clean" and q > a * (1.0 + tol_eq):
            failures.append(
                f"{sc}: queue {q:.3f} kJ worse than analytic {a:.3f} kJ"
            )
        if sc in MUST_WIN and not q < a:
            failures.append(
                f"{sc}: queue {q:.3f} kJ not strictly below "
                f"analytic {a:.3f} kJ"
            )
        # parity is one-sided: the guard is against queue-aware training
        # SACRIFICING clean performance for congestion robustness; beating
        # the analytic policy on clean is a win, not a parity violation
        if sc == "clean" and q > a * (1.0 + tol_clean):
            failures.append(
                f"clean: queue {q:.3f} kJ more than {tol_clean:.0%} above "
                f"analytic {a:.3f} kJ"
            )
    if failures:
        raise SystemExit("gauntlet acceptance FAILED:\n  " +
                         "\n  ".join(failures))
    print("\ngauntlet acceptance PASSED: queue-trained policy is no worse "
          "everywhere, strictly better on " + ", ".join(MUST_WIN) +
          ", clean parity held")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="reddit")
    ap.add_argument("--batch", type=int, default=1000)
    ap.add_argument("--steps", type=int, default=128,
                    help="total eval train steps per run (bounds runtime)")
    ap.add_argument("--steps-per-epoch", type=int, default=16)
    ap.add_argument("--iterations", type=int, default=4_000,
                    help="DQN training iterations per env")
    ap.add_argument("--train-epochs", type=int, default=30,
                    help="episode length (epochs) inside the training envs")
    ap.add_argument("--n-envs", type=int, default=64,
                    help="vectorized training environments")
    ap.add_argument("--train-envs", default=",".join(TRAIN_ENVS))
    ap.add_argument("--scenarios", default="",
                    help="comma list (default: every non-parametric "
                         "registry scenario)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="skip Algorithm-1 calibration (published constants)")
    ap.add_argument("--force", action="store_true",
                    help="retrain policies even if artifacts exist")
    ap.add_argument("--check", action="store_true",
                    help="assert the ISSUE-3 acceptance criteria")
    args = ap.parse_args()
    args.train_envs = args.train_envs.split(",")

    steps_per_epoch = args.steps_per_epoch
    n_epochs = max(args.steps // steps_per_epoch, 3)
    cfg0 = base_cfg(args.dataset, args.batch)
    cfg0 = dataclasses.replace(
        cfg0, n_epochs=n_epochs, steps_per_epoch=steps_per_epoch,
        seed=args.seed,
    )
    print(f"building shared trace ({args.dataset}, B={args.batch}, "
          f"{n_epochs}x{steps_per_epoch} steps)...", flush=True)
    bundle = gt.build_trace(cfg0)

    pools = build_pools(args, cfg0, bundle)
    q_fns = train_policies(args, pools, cfg0)
    rows = run_gauntlet(args, cfg0, bundle, q_fns)

    result = {
        "dataset": args.dataset, "batch": args.batch,
        "n_epochs": n_epochs, "steps_per_epoch": steps_per_epoch,
        "iterations": args.iterations, "train_envs": args.train_envs,
        "seed": args.seed, "rows": rows,
    }
    path = save_json("policy_gauntlet", result)
    print(f"\nwrote {path}")
    if args.check:
        check_acceptance(rows)


if __name__ == "__main__":
    main()
