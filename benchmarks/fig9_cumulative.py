"""Fig. 9: cumulative energy over epochs under congestion.

Claim: GreenDyGNN accumulates less energy than all baselines, gap widening
during congested epochs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import DATASETS, METHODS, fmt_row, save_json, sweep


def main(batch: int = 2000) -> list[str]:
    sw = sweep()
    rows, table = [], []
    for ds in DATASETS:
        curves = {
            m: sw.run(ds, batch, m, True).meter.cumulative_kj().tolist()
            for m in METHODS
        }
        table.append({"dataset": ds, **curves})
        final = {m: curves[m][-1] for m in METHODS}
        gap_vs_rapid = final["rapidgnn"] - final["greendygnn"]
        rows.append(fmt_row(
            f"fig9/{ds}/final_cumulative_kj",
            "|".join(f"{m}={final[m]:.2f}" for m in METHODS),
        ))
        rows.append(fmt_row(
            f"fig9/{ds}/saved_vs_rapidgnn_kj", f"{gap_vs_rapid:.2f}",
            "paper: gap widens during congested epochs",
        ))
        # monotone widening check: gap at end >= gap at 1/3 of the run
        g = np.asarray(curves["rapidgnn"]) - np.asarray(curves["greendygnn"])
        rows.append(fmt_row(
            f"fig9/{ds}/gap_widens", bool(g[-1] >= g[len(g) // 3]),
        ))
    save_json("fig9_cumulative", table)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
