"""Pipeline overlap microbenchmark: is adaptation *measurably* free?

Runs the REAL threaded pipeline (repro.pipeline) on the synthetic Reddit
analogue at the paper's default W=16 and reports measured quantities:

  * overlap efficiency — fraction of builder (plan + bulk fetch) wall time
    hidden behind consumer step compute (paper claim: rebuilds overlap so
    well that adaptation is "effectively free"; we require >= 50% hidden),
  * swap latency — the atomic generation-tagged buffer promotion,
  * prefetch lead/wait — how far ahead the Stage-3 depth-Q queue runs,
  * parity — threaded vs synchronous hit/miss stream + per-owner rows.
"""
from __future__ import annotations

from benchmarks.common import fmt_row, save_json

from repro.pipeline.parity import compare_runs
from repro.train import gnn_trainer as gt


def main(
    dataset: str = "reddit",
    batch: int = 2000,
    window: int = 16,
    n_epochs: int = 6,
    steps_per_epoch: int = 32,
) -> list[str]:
    import dataclasses

    cfg = gt.RunConfig(
        method="static_w", dataset=dataset, batch_size=batch,
        n_epochs=n_epochs, steps_per_epoch=steps_per_epoch,
        static_window=window,
    )
    bundle = gt.build_trace(cfg)
    res_sync = gt.run(cfg, bundle)
    res = gt.run(dataclasses.replace(cfg, async_pipeline=True), bundle)
    parity = compare_runs(res_sync, res)
    rep = res.pipeline
    s = rep.summary()
    consumer_s = float(res.meter.wall_s)

    rows = [
        fmt_row(f"pipeline/{dataset}/W", window),
        fmt_row(f"pipeline/{dataset}/n_rebuilds", rep.n_rebuilds),
        fmt_row(
            f"pipeline/{dataset}/builder_wall_ms",
            round(1e3 * rep.builder_wall_s, 3),
        ),
        fmt_row(
            f"pipeline/{dataset}/exposed_wait_ms",
            round(1e3 * rep.exposed_wait_s, 3),
        ),
        fmt_row(
            f"pipeline/{dataset}/overlap_efficiency",
            round(rep.overlap_efficiency, 4),
            "paper: rebuild hidden behind compute; target >= 0.5",
        ),
        fmt_row(
            f"pipeline/{dataset}/swap_latency_us",
            round(1e6 * rep.swap_latency_s, 1),
            "atomic generation-tagged promotion",
        ),
        fmt_row(
            f"pipeline/{dataset}/prefetch_mean_lead_ms",
            round(1e3 * rep.prefetch_mean_lead_s, 3),
            f"Stage-3 queue depth Q={cfg.prefetch_depth}",
        ),
        fmt_row(
            f"pipeline/{dataset}/prefetch_wait_ms",
            round(1e3 * rep.prefetch_wait_s, 3),
        ),
        fmt_row(
            f"pipeline/{dataset}/parity",
            "OK" if parity.ok else "MISMATCH",
            f"{parity.n_steps} steps, {parity.mismatched_steps} mismatched",
        ),
    ]
    save_json(
        "pipeline_overlap",
        {
            **s,
            "dataset": dataset,
            "window": window,
            "consumer_wall_modeled_s": consumer_s,
            "parity_ok": parity.ok,
            "parity_steps": parity.n_steps,
        },
    )
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
