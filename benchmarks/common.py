"""Shared benchmark harness: traces, policies, and the method sweep.

All figures/tables reuse ONE sweep result store so the full `benchmarks.run`
stays in CPU-minutes: traces are built once per (dataset, batch) and every
method replays the identical trace under the identical congestion schedule
(matching the paper's "all four methods experience identical congestion").
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.train import gnn_trainer as gt
from repro.train import policy as pol

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
DATASETS = ["reddit", "ogbn-products", "ogbn-papers100m"]
BATCH_SIZES = [1000, 2000, 3000]
METHODS = ["dgl", "bgl", "rapidgnn", "greendygnn"]
ABLATIONS = ["static_w", "greendygnn_nocw"]

N_EPOCHS = 14
STEPS_PER_EPOCH = 32
WARMUP = 2


def base_cfg(dataset: str, batch: int, **kw) -> gt.RunConfig:
    return gt.RunConfig(
        dataset=dataset, batch_size=batch, n_epochs=N_EPOCHS,
        steps_per_epoch=STEPS_PER_EPOCH, warmup_epochs=WARMUP, **kw,
    )


class Sweep:
    """Lazily runs and caches every (dataset, batch, method, condition)."""

    def __init__(self):
        self._traces: dict = {}
        self._runs: dict = {}
        self._q_fn = None

    @property
    def q_fn(self):
        if self._q_fn is None:
            tables = [
                pol.calibrate_table_from_bundle(
                    self.trace(ds, 2000), base_cfg(ds, 2000)
                )
                for ds in DATASETS
            ]
            pool = pol.make_params_pool(tables)
            self._q_fn, _ = pol.get_or_train_policy(pool, name="qnet_main")
        return self._q_fn

    def trace(self, dataset: str, batch: int):
        key = (dataset, batch)
        if key not in self._traces:
            self._traces[key] = gt.build_trace(base_cfg(dataset, batch))
        return self._traces[key]

    def run(self, dataset: str, batch: int, method: str,
            congested: bool) -> gt.RunResult:
        key = (dataset, batch, method, congested)
        if key not in self._runs:
            q_fn = (
                self.q_fn if method.startswith("greendygnn") else None
            )
            cfg = base_cfg(dataset, batch, method=method,
                           congested=congested, q_fn=q_fn)
            self._runs[key] = gt.run(cfg, self.trace(dataset, batch))
        return self._runs[key]

    def totals(self, dataset, batch, method, congested) -> dict:
        return self.run(dataset, batch, method, congested).totals()


_GLOBAL_SWEEP: Sweep | None = None


def sweep() -> Sweep:
    global _GLOBAL_SWEEP
    if _GLOBAL_SWEEP is None:
        _GLOBAL_SWEEP = Sweep()
    return _GLOBAL_SWEEP


def save_json(name: str, data) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    return path


def fmt_row(name: str, value, derived: str = "") -> str:
    return f"{name},{value},{derived}"
