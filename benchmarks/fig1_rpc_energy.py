"""Fig. 1: per-RPC energy decomposed into initiation vs payload cost.

Claim reproduced: at GNN-typical request sizes (tens to hundreds of nodes)
initiation accounts for 90-99% of per-RPC energy; the crossover where
payload dominates is above ~1000 nodes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row, save_json
from repro.core import cost_model as cm


def main() -> list[str]:
    params = cm.CostModelParams()
    sizes = [10, 50, 100, 500, 1_000, 5_000, 10_000, 50_000]
    rows, table = [], []
    for n in sizes:
        e_init, e_pay = cm.rpc_energy_breakdown(params, jnp.asarray(float(n)))
        share = float(e_init / (e_init + e_pay))
        table.append({"batch_nodes": n, "initiation_share": share,
                      "e_init_mj": float(e_init) * 1e3,
                      "e_payload_mj": float(e_pay) * 1e3})
        rows.append(fmt_row(f"fig1/initiation_share@N={n}", f"{share:.4f}"))

    shares = {t["batch_nodes"]: t["initiation_share"] for t in table}
    claim_small = all(shares[n] > 0.89 for n in (10, 50, 100))
    crossover = next(n for n in sizes if shares[n] < 0.5)
    rows.append(fmt_row("fig1/claim_90_99pct_at_gnn_sizes", claim_small,
                        "paper: 90-99% at tens-hundreds of nodes"))
    rows.append(fmt_row("fig1/payload_crossover_nodes", crossover,
                        "paper: crossover above ~1000 nodes"))
    save_json("fig1_rpc_energy", table)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
