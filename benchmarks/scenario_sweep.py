"""Per-scenario energy/time sweep across methods on the net fabric.

Runs every registry scenario (or a chosen subset) end-to-end through the
trace-driven trainer for dgl / bgl / static (static_w) / adaptive
(heuristic) and prints a Table-I style grid: total energy, mean epoch
time, mean hit rate, mean effective sigma. The adaptive method needs no
pretrained artifact, so the whole sweep is self-contained.

    PYTHONPATH=src python benchmarks/scenario_sweep.py --steps 120
    PYTHONPATH=src python benchmarks/scenario_sweep.py \
        --scenarios clean,incast,trace:mytrace.json --methods dgl,heuristic

``--workers P`` (P > 1) runs every cell as a concurrent P-worker cluster
over ONE shared requester-aware fabric (``repro.train.cluster``): the
scenario's background processes become optional overlays on top of the
*emergent* cross-worker congestion, and the reported energy is the
cluster total summed over the P trainers (see ``benchmarks/
cluster_sweep.py`` for the dedicated emergent-vs-injected comparison).

``--check-clean-parity`` additionally runs the closed-form path on the
clean scenario's config and asserts the fabric totals agree within 5%
(the acceptance cross-check), plus bit-reproducibility of the hit/miss
stream across two seeded fabric runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import numpy as np

try:  # repo root (python -m benchmarks.scenario_sweep / python benchmarks/..)
    from benchmarks.common import RESULTS_DIR, base_cfg, save_json
except ImportError:  # cwd = benchmarks/
    from common import RESULTS_DIR, base_cfg, save_json

from repro.net import ScenarioRegistry
from repro.train import gnn_trainer as gt

DEFAULT_METHODS = ["dgl", "bgl", "static_w", "heuristic"]
METHOD_LABEL = {"static_w": "static", "heuristic": "adaptive"}


def default_scenarios() -> list[str]:
    return [n for n in ScenarioRegistry.names() if ":" not in n]


def run_sweep(args) -> dict:
    steps_per_epoch = args.steps_per_epoch
    n_epochs = max(args.steps // steps_per_epoch, 2)
    workers = max(int(getattr(args, "workers", 1)), 1)
    cfg0 = base_cfg(args.dataset, args.batch)
    cfg0 = dataclasses.replace(
        cfg0, n_epochs=n_epochs, steps_per_epoch=steps_per_epoch,
        seed=args.seed,
    )
    print(f"building shared trace ({args.dataset}, B={args.batch}, "
          f"{n_epochs}x{steps_per_epoch} steps"
          + (f", P={workers} workers" if workers > 1 else "")
          + ")...", flush=True)
    if workers > 1:
        from repro.train.cluster import (
            ClusterConfig, build_cluster_traces, run_cluster,
        )

        bundles = build_cluster_traces(cfg0, workers)
    else:
        bundle = gt.build_trace(cfg0)

    scenarios = (
        args.scenarios.split(",") if args.scenarios else default_scenarios()
    )
    methods = args.methods.split(",")

    rows: dict = {}
    header = f"{'scenario':>16} " + "".join(
        f"{METHOD_LABEL.get(m, m):>12}" for m in methods
    )
    print("\ntotal energy [kJ] per scenario x method "
          "(epoch time / hit rate in the JSON)")
    print(header)
    for sc in scenarios:
        rows[sc] = {}
        cells = []
        for m in methods:
            cfg_m = dataclasses.replace(
                cfg0, method=m, scenario=sc, trace=args.trace,
            )
            if workers > 1:
                rep = run_cluster(
                    cfg_m, ClusterConfig(n_workers=workers),
                    trace_bundles=bundles,
                )
                if args.trace and rep.trace is not None:
                    _save_cell_trace(rep.trace, sc, m, workers)
                t = rep.totals_kj()
                r0 = rep.results[0]
                rows[sc][m] = {
                    "total_kj": t["total_kj"],
                    "gpu_kj": t["gpu_kj"],
                    "cpu_kj": t["cpu_kj"],
                    "wall_s": t["wall_s"],
                    "mean_epoch_ms": r0.meter.mean_epoch_time() * 1e3,
                    "hit_rate": float(np.mean([
                        float(r.hit_rate_per_epoch.mean())
                        for r in rep.results
                    ])),
                    "mean_sigma": float(r0.sigma_trace.mean()),
                    "queue_s": rep.total_queue_s,
                    "per_worker": rep.per_worker(),
                }
            else:
                r = gt.run(cfg_m, bundle)
                if args.trace and r.trace is not None:
                    _save_cell_trace(r.trace, sc, m, workers)
                t = r.totals()
                rows[sc][m] = {
                    "total_kj": t["total_kj"],
                    "gpu_kj": t["gpu_kj"],
                    "cpu_kj": t["cpu_kj"],
                    "wall_s": t["wall_s"],
                    "mean_epoch_ms": r.meter.mean_epoch_time() * 1e3,
                    "hit_rate": float(r.hit_rate_per_epoch.mean()),
                    "mean_sigma": float(r.sigma_trace.mean()),
                }
            cells.append(f"{rows[sc][m]['total_kj']:12.3f}")
        sig = rows[sc][methods[0]]["mean_sigma"]
        print(f"{sc:>16} " + "".join(cells) + f"   (sigma~{sig:.2f})")
    return {
        "dataset": args.dataset, "batch": args.batch,
        "n_epochs": n_epochs, "steps_per_epoch": steps_per_epoch,
        "seed": args.seed, "workers": workers, "rows": rows,
    }


def _save_cell_trace(payload, sc, method, workers) -> None:
    """Reconcile and persist one cell's greentrace payload."""
    from repro.obs import reconcile, write_trace

    reconcile(payload)  # hard-fail on a broken energy ledger
    safe = sc.replace(":", "_").replace("/", "_")
    path = write_trace(
        os.path.join(
            RESULTS_DIR, "traces",
            f"scenario_sweep_p{workers}_{safe}_{method}.json",
        ),
        payload,
    )
    print(f"    trace -> {path}")


def check_clean_parity(args) -> None:
    """Acceptance: fabric(clean) vs closed form within 5%; bit-repro."""
    cfg = base_cfg(args.dataset, args.batch)
    cfg = dataclasses.replace(
        cfg, method="static_w",
        n_epochs=max(args.steps // args.steps_per_epoch, 2),
        steps_per_epoch=args.steps_per_epoch, congested=False,
        seed=args.seed,
    )
    bundle = gt.build_trace(cfg)
    closed = gt.run(cfg, bundle)
    fab1 = gt.run(dataclasses.replace(cfg, scenario="clean"), bundle)
    fab2 = gt.run(dataclasses.replace(cfg, scenario="clean"), bundle)

    e_c = closed.totals()["total_kj"]
    e_f = fab1.totals()["total_kj"]
    rel = abs(e_f - e_c) / e_c
    print(f"\nclean parity: closed={e_c:.4f} kJ fabric={e_f:.4f} kJ "
          f"rel={rel:.3%}")
    assert rel < 0.05, f"clean fabric diverges from closed form: {rel:.3%}"

    np.testing.assert_array_equal(fab1.step_hits, fab2.step_hits)
    np.testing.assert_array_equal(fab1.step_misses, fab2.step_misses)
    np.testing.assert_array_equal(
        fab1.fetched_rows_by_owner, fab2.fetched_rows_by_owner
    )
    print("bit-reproducibility: identical hit/miss stream and per-owner "
          "fetched rows across two fabric runs")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="reddit")
    ap.add_argument("--batch", type=int, default=1000)
    ap.add_argument("--steps", type=int, default=128,
                    help="total train steps per run (bounds runtime)")
    ap.add_argument("--steps-per-epoch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenarios", default="",
                    help="comma list (default: every non-parametric "
                         "registry scenario)")
    ap.add_argument("--methods", default=",".join(DEFAULT_METHODS))
    ap.add_argument("--workers", type=int, default=1,
                    help="P > 1: run each cell as a concurrent P-worker "
                         "cluster over one shared fabric (emergent "
                         "cross-worker congestion + the scenario overlay)")
    ap.add_argument("--trace", action="store_true",
                    help="capture a greentrace payload per cell (written "
                         "under results/bench/traces/, reconciled)")
    ap.add_argument("--check-clean-parity", action="store_true")
    args = ap.parse_args()

    result = run_sweep(args)
    path = save_json("scenario_sweep", result)
    print(f"\nwrote {path}")
    if args.check_clean_parity:
        check_clean_parity(args)


if __name__ == "__main__":
    main()
