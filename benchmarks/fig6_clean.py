"""Fig. 6: total energy under clean conditions.

Claim: GreenDyGNN matches the strongest static baseline within ~2% — the
adaptive controller causes no cache churn when the network is stable.
"""
from __future__ import annotations

from benchmarks.common import DATASETS, METHODS, fmt_row, save_json, sweep


def main(batch: int = 2000) -> list[str]:
    sw = sweep()
    rows, table = [], []
    for ds in DATASETS:
        entry = {"dataset": ds}
        for m in METHODS:
            entry[m] = round(sw.totals(ds, batch, m, False)["total_kj"], 3)
        gap = 100 * (entry["greendygnn"] / entry["rapidgnn"] - 1)
        entry["gap_vs_rapidgnn_pct"] = round(gap, 2)
        table.append(entry)
        rows.append(fmt_row(
            f"fig6/{ds}/clean_total_kj",
            "|".join(f"{m}={entry[m]:.2f}" for m in METHODS),
        ))
        rows.append(fmt_row(
            f"fig6/{ds}/adaptive_gap_pct", f"{gap:.2f}",
            "paper: within 2% of RapidGNN",
        ))
    save_json("fig6_clean", table)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
