"""Roofline table aggregation: reads results/dryrun/*.json into the
EXPERIMENTS.md table (all 40 baseline cells, single-pod)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import fmt_row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(mesh: str = "single") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main() -> list[str]:
    rows = []
    recs = load_records("single")
    if not recs:
        return [fmt_row("roofline/error", "no dry-run results",
                        "run python -m repro.launch.dryrun --all first")]
    for r in recs:
        t = r["roofline"]
        rows.append(fmt_row(
            f"roofline/{r['arch']}/{r['shape']}",
            f"{t['roofline_fraction']:.3f}",
            f"dom={t['dominant']};compute={t['compute_s']:.2e}s;"
            f"memory={t['memory_s']:.2e}s;collective={t['collective_s']:.2e}s;"
            f"peak={r['memory']['peak_estimate_gb']}GB",
        ))
    multi = load_records("multi")
    rows.append(fmt_row("roofline/cells_single", len(recs), "expect 44"))
    rows.append(fmt_row("roofline/cells_multi", len(multi), "expect 44"))
    doms = {}
    for r in recs:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    rows.append(fmt_row("roofline/dominant_histogram",
                        "|".join(f"{k}={v}" for k, v in sorted(doms.items()))))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
