"""Table II / Fig. 11: ablation under congestion at B=2000.

  w/o RL            -> static windowed cache at W=16
  w/o Cost Weights  -> RL adapts W, allocation forced uniform
  full GreenDyGNN   -> both levers

Claim: both components contribute; RL window adaptation gives the larger
share, per-owner cost weighting adds on top.
"""
from __future__ import annotations

from benchmarks.common import DATASETS, fmt_row, save_json, sweep

VARIANTS = ["static_w", "greendygnn_nocw", "greendygnn"]


def main(batch: int = 2000) -> list[str]:
    sw = sweep()
    rows, table = [], []
    for ds in DATASETS:
        entry = {"dataset": ds}
        for v in VARIANTS:
            entry[v] = round(sw.totals(ds, batch, v, True)["total_kj"], 3)
        table.append(entry)
        full = entry["greendygnn"]
        rows.append(fmt_row(
            f"table2/{ds}/kj",
            f"w/o_RL={entry['static_w']}|w/o_CW={entry['greendygnn_nocw']}"
            f"|full={full}",
        ))
        rows.append(fmt_row(
            f"table2/{ds}/full_beats_both_ablations",
            full <= entry["static_w"] and full <= entry["greendygnn_nocw"],
            "paper: both components contribute",
        ))
    save_json("table2_ablation", table)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
