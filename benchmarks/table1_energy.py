"""Table I + Fig. 4: energy and epoch time under congestion, all methods x
datasets x batch sizes.

Claims reproduced:
  * GreenDyGNN lowest total energy in most configurations,
  * savings vs Default DGL in the tens of percent (paper: 27-43%),
  * consistently below RapidGNN (paper: 4-24%),
  * fastest epoch time.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BATCH_SIZES, DATASETS, METHODS, fmt_row, save_json, sweep,
)


def main() -> list[str]:
    sw = sweep()
    table, rows = [], []
    for ds in DATASETS:
        for b in BATCH_SIZES:
            entry = {"dataset": ds, "batch": b}
            for m in METHODS:
                r = sw.run(ds, b, m, congested=True)
                t = r.totals()
                entry[m] = {
                    "gpu_kj": round(t["gpu_kj"], 3),
                    "cpu_kj": round(t["cpu_kj"], 3),
                    "total_kj": round(t["total_kj"], 3),
                    "epoch_time_s": round(r.meter.mean_epoch_time(), 4),
                }
            table.append(entry)

    n_best = n_fastest = 0
    dgl_savings, rapid_savings = [], []
    for e in table:
        totals = {m: e[m]["total_kj"] for m in METHODS}
        ets = {m: e[m]["epoch_time_s"] for m in METHODS}
        if min(totals, key=totals.get) == "greendygnn":
            n_best += 1
        if min(ets, key=ets.get) == "greendygnn":
            n_fastest += 1
        dgl_savings.append(1 - totals["greendygnn"] / totals["dgl"])
        rapid_savings.append(1 - totals["greendygnn"] / totals["rapidgnn"])
        rows.append(fmt_row(
            f"table1/{e['dataset']}/B={e['batch']}/total_kj",
            "|".join(f"{m}={totals[m]:.2f}" for m in METHODS),
        ))

    rows.append(fmt_row("table1/greendygnn_best_of_9", f"{n_best}/9",
                        "paper: lowest in 8 of 9"))
    rows.append(fmt_row("table1/greendygnn_fastest_of_9", f"{n_fastest}/9",
                        "paper: fastest in 9 of 9"))
    rows.append(fmt_row(
        "table1/savings_vs_dgl_pct",
        f"{100 * min(dgl_savings):.1f}..{100 * max(dgl_savings):.1f}",
        "paper: 27..43",
    ))
    rows.append(fmt_row(
        "table1/savings_vs_rapidgnn_pct",
        f"{100 * min(rapid_savings):.1f}..{100 * max(rapid_savings):.1f}",
        "paper: 4..24",
    ))
    save_json("table1_energy", table)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
